//! dcinfer CLI: regenerate every table/figure of the paper and run the
//! serving tier.
//!
//! ```text
//! dcinfer characterize          Table 1
//! dcinfer demand                Fig 1
//! dcinfer roofline [--model M]  Fig 3
//! dcinfer fleet [--requests N]  Fig 4
//! dcinfer shapes                Fig 5
//! dcinfer mine [--top K]        §3.3 fusion opportunities
//! dcinfer disagg                §4 tier bandwidth
//! dcinfer serve [--requests N] [--executors E] [--qps Q] [--models recsys,nmt,cv]
//!               [--backend pjrt|native] [--precision fp32|fp16|i8acc32|i8acc16]
//!               [--threads T] [--max-queue D]
//!               [--listen ADDR] [--duration S] [--replica-label L] [--artifacts DIR]
//!               [--sparse-shards N] [--sparse-cache ROWS] [--sparse-replication R]
//!               [--remote-shards ADDR,ADDR,...] [--seq-sessions N] [--faults SPEC]
//! dcinfer loadgen --connect ADDR [--qps Q] [--requests N]
//!                 [--mix recsys:8,cv:1,nmt:1] [--deadline-ms D] [--seed S]
//!                 [--demand diurnal:peak=1,trough=0.45,peak_hour=20|trace:FILE]
//!                 [--demand-period SECS] [--skew uniform|zipf:S]
//!                 [--artifacts DIR] [--faults SPEC]
//!                 [--seq geom:MEAN|uniform:LO,HI] [--max-len N]
//! dcinfer shard-serve [--listen ADDR] [--faults SPEC]
//! dcinfer cluster [--replicas N] [--shard-procs M] [--sparse-replication R]
//!                 [--requests N] [--qps Q] [--mix ...] [--seed S]
//!                 [--backend B] [--precision P] [--artifacts DIR] [--faults SPEC]
//! dcinfer autoscale [--requests N] [--peak-qps Q] [--period SECS]
//!                   [--min-executors A] [--max-executors B] [--interval-ms T]
//!                   [--models M] [--demand SPEC] [--skew SPEC] [--seed S]
//! ```
//!
//! `shard-serve` runs one standalone embedding-shard server (§4
//! dis-aggregation as a real process): an empty `ShardStore` behind the
//! wire protocol's shard frames, populated by its serving-tier clients.
//! `serve --remote-shards` points a frontend's sparse tier at such
//! processes instead of in-process shard threads — same numerics, bit
//! for bit. `cluster` spawns a loopback mini-fleet (M shard processes,
//! N serving replicas wired to them, one `ClusterRouter` in front),
//! drives loadgen through the router and prints the per-replica fleet
//! view.
//!
//! `--sparse-shards` dis-aggregates the embedding tables of native-backend
//! lanes across an in-process sharded sparse tier with a hot-row cache
//! (§4); per-table hit rates print with the serving metrics.
//!
//! `--threads` sets intra-op GEMM workers per FC/conv on the native
//! backend (0 = all cores): the §3.1 cores-per-op vs executors trade —
//! more `--executors` maximizes throughput, more `--threads` cuts
//! per-batch latency at small batch.
//!
//! `serve --listen ADDR` swaps the self-driving synthetic loop for the
//! network serving plane: a TCP server speaking the versioned wire
//! protocol, with §2.3 admission control (`--max-queue` bounds each
//! lane's depth; over it, requests are shed as `Overloaded` instead of
//! queueing past their deadline). `loadgen` is the matching open-loop
//! client: Poisson arrivals at `--qps` across a weighted `--mix` of
//! model families, reporting p50/p99/p999 latency, goodput (answered
//! within deadline) and the shed rate.
//!
//! When `serve --listen` loads the `nmt` family it also brings up the
//! sequence plane (§2.1.3): a server-owned decode loop with step-level
//! continuous batching. `loadgen --seq geom:12` drives it — one
//! `SeqSubmit` per sequence, output lengths drawn from the given
//! distribution, tokens streamed back as they decode — and reports
//! tokens/sec, time-to-first-token, inter-token and per-token latency.
//! `--seq-sessions` bounds the server's session table (over it,
//! submits shed as `Overloaded`, same §2.3 contract as `--max-queue`).
//!
//! `--faults SPEC` (or the `DCINFER_FAULTS` env var) installs a
//! deterministic fault-injection plan on every transport this process
//! opens — delays, drops, resets, partial writes, corruption and
//! throttling, keyed by peer label and connection index so the same
//! seed replays bit-identically (see [`dcinfer::faultnet`]). `cluster`
//! forwards the spec to every child it spawns, so one flag
//! chaos-tests the whole mini-fleet.
//!
//! `loadgen --demand` replays the paper's Fig 1 demand shape against a
//! live server: arrivals stay open-loop Poisson but the instantaneous
//! rate follows a diurnal curve (or a `trace:FILE` of samples), with
//! one simulated day compressed into `--demand-period` seconds.
//! `--skew zipf:S` draws embedding rows from a seeded Zipf instead of
//! uniformly, so a sparse tier's hot-row cache sees production-like
//! reuse. Demand-modulated runs also print a per-interval timeline
//! (offered qps, goodput, shed, p99 per slice of the run).
//!
//! `autoscale` closes the loop: a loopback serving tier, the same
//! demand-replayed loadgen, and an
//! [`dcinfer::autoscale::AutoscaleController`] polling the serving
//! metrics on `--interval-ms`, resizing the live executor pool between
//! `--min-executors` and `--max-executors` through a simulated peak —
//! printing every scale decision and the SLO/shed summary.
//!
//! Without `artifacts/manifest.json` both subcommands fall back to the
//! self-synthesized fixture (native backend), so a loopback
//! serve/loadgen pair runs out of the box.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use dcinfer::autoscale::{format_events, AutoscaleController, ScalePolicy};
use dcinfer::cluster::{ChildProc, ClusterRouter, RouterConfig, ShardServer, ShardServerConfig};
use dcinfer::coordinator::{
    disagg_bandwidth, ClientResponse, DcClient, FrontendConfig, IndexSkew, InferError,
    ModelService, SeqClientEvent, SeqConfig, SeqEngine, SeqFinish, ServerConfig,
    ServingFrontend, ServingServer,
};
use dcinfer::models::{CvService, LengthDistribution, NmtService, RecSysService};
use dcinfer::runtime::Manifest;
use dcinfer::util::stats::Samples;
use dcinfer::fleet::{demand_series, simulate_fleet, DemandCurve, FleetConfig};
use dcinfer::graph::{mine_frequent_subgraphs, rank_opportunities, Net};
use dcinfer::models::{representative_zoo, ModelDesc};
use dcinfer::perfmodel::roofline::fig3_capacities;
use dcinfer::perfmodel::{characterize_zoo, roofline_curve, shape_survey, DeviceSpec};
use dcinfer::report;
use dcinfer::util::rng::Pcg32;

fn parse_flags(args: &[String]) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            out.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

fn zoo_models() -> Vec<ModelDesc> {
    representative_zoo().into_iter().map(|e| e.desc).collect()
}

/// `--faults SPEC` installs a deterministic fault-injection plan for
/// every transport this process opens (`DCINFER_FAULTS` is the env
/// equivalent, picked up in `main`).
fn install_faults_flag(flags: &BTreeMap<String, String>) -> Result<()> {
    if let Some(spec) = flags.get("faults") {
        dcinfer::faultnet::install_spec(spec).with_context(|| format!("--faults {spec:?}"))?;
        println!("fault injection active: {spec}\n");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    if dcinfer::faultnet::install_from_env()? {
        println!("fault injection active: DCINFER_FAULTS\n");
    }

    match cmd {
        "characterize" => cmd_characterize(),
        "demand" => cmd_demand(),
        "roofline" => cmd_roofline(&flags),
        "fleet" => cmd_fleet(&flags),
        "shapes" => cmd_shapes(),
        "mine" => cmd_mine(&flags),
        "disagg" => cmd_disagg(),
        "codesign" => cmd_codesign(),
        "serve" => cmd_serve(&flags),
        "loadgen" => cmd_loadgen(&flags),
        "shard-serve" => cmd_shard_serve(&flags),
        "cluster" => cmd_cluster(&flags),
        "autoscale" => cmd_autoscale(&flags),
        _ => {
            println!("dcinfer — data-center DL inference characterization & serving");
            println!(
                "subcommands: characterize demand roofline fleet shapes mine disagg codesign \
                 serve loadgen shard-serve cluster autoscale"
            );
            Ok(())
        }
    }
}

/// Table 1.
fn cmd_characterize() -> Result<()> {
    println!("== Table 1: resource requirements of representative DL inference workloads ==\n");
    let rows = characterize_zoo(&zoo_models());
    report::print_table1(&rows);
    Ok(())
}

/// Fig 1.
fn cmd_demand() -> Result<()> {
    println!("== Fig 1: server demand for DL inference across data centers ==\n");
    let services = dcinfer::fleet::demand::default_services();
    let series = demand_series(&services, 9);
    print!("{:<8}", "quarter");
    for s in &services {
        print!("{:>24}", s.name);
    }
    println!("{:>10}", "total");
    for p in &series {
        print!("{:<8}", format!("Q{}", p.quarter));
        for v in &p.per_service {
            print!("{v:>24.1}");
        }
        println!("{:>10.1}", p.total);
    }
    println!("\ngrowth over 8 quarters: {:.1}x", series[8].total / series[0].total);
    Ok(())
}

/// Fig 3.
fn cmd_roofline(flags: &BTreeMap<String, String>) -> Result<()> {
    println!("== Fig 3: roofline on a hypothetical 100 TOP/s, 100 GB/s DRAM accelerator ==");
    println!("(int8 parameters; on-chip capacity sweep at 1 and 10 TB/s on-chip BW)\n");
    let filter = flags.get("model").cloned().unwrap_or_default();
    let caps = fig3_capacities();
    for m in zoo_models() {
        if !filter.is_empty() && !m.name.contains(&filter) {
            continue;
        }
        let c1 = roofline_curve(&m, &caps, 1.0);
        let c10 = roofline_curve(&m, &caps, 10.0);
        report::print_roofline_curves(&m.name, &c1, &c10);
        println!();
    }
    Ok(())
}

/// Fig 4.
fn cmd_fleet(flags: &BTreeMap<String, String>) -> Result<()> {
    println!("== Fig 4: time spent in operators across the (simulated) fleet ==\n");
    let requests = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(2000);
    let zoo = representative_zoo();
    let dev = DeviceSpec::xeon_fp32();
    let agent = simulate_fleet(&zoo, &dev, &FleetConfig { requests, ..Default::default() });
    report::print_breakdown(&agent.breakdown());
    println!("\nroofline inefficiency (measured/predicted) by bucket:");
    for (bucket, ineff) in agent.inefficiency_by_bucket() {
        println!("  {bucket:<12} {ineff:.2}x");
    }
    println!("\noptimization benefit (fraction of fleet time recoverable):");
    for bucket in ["FC", "Embedding", "TensorManip", "Conv"] {
        println!("  {bucket:<12} {:.1}%", agent.optimization_benefit(bucket) * 100.0);
    }
    Ok(())
}

/// Fig 5.
fn cmd_shapes() -> Result<()> {
    println!("== Fig 5: activation/weight matrix shapes across the zoo ==\n");
    let pts = shape_survey(&zoo_models());
    println!(
        "{:<28} {:<14} {:>9} {:>7} {:>7} {:>5} {:>10}",
        "model", "class", "M", "N", "K", "G", "intensity"
    );
    for p in pts.iter().take(60) {
        println!(
            "{:<28} {:<14} {:>9} {:>7} {:>7} {:>5} {:>10.1}",
            p.model,
            format!("{:?}", p.class),
            p.m,
            p.n,
            p.k,
            p.groups,
            p.intensity()
        );
    }
    let narrow = pts.iter().filter(|p| p.is_matrix_vector_like()).count();
    println!(
        "\n{} shapes total; {} ({:.0}%) are matrix-vector-like (M or N < 32)",
        pts.len(),
        narrow,
        narrow as f64 / pts.len() as f64 * 100.0
    );
    Ok(())
}

/// §3.3 fusion mining.
fn cmd_mine(flags: &BTreeMap<String, String>) -> Result<()> {
    println!("== §3.3: frequent-subgraph mining + roofline fusion ranking ==\n");
    let top_k = flags.get("top").and_then(|v| v.parse().ok()).unwrap_or(10);
    let zoo = representative_zoo();
    let nets: Vec<(Net, f64)> =
        zoo.iter().map(|e| (Net::from_model(&e.desc, 4), e.fleet_weight * 1000.0)).collect();
    let mined = mine_frequent_subgraphs(&nets, 3, 1.0);
    println!("{} candidate subgraphs mined", mined.len());
    let dev = DeviceSpec::xeon_fp32();
    let top = rank_opportunities(&mined, &dev, top_k);
    println!("\ntop-{top_k} opportunities (by fleet-weighted saving):");
    println!("{:<40} {:>10} {:>9} {:>14}", "subgraph", "freq", "speedup", "saving (ms)");
    for o in &top {
        println!(
            "{:<40} {:>10.0} {:>8.2}x {:>14.3}",
            o.signature,
            o.frequency,
            o.speedup(),
            o.weighted_saving * 1e3
        );
    }
    Ok(())
}

/// §4 disaggregation bandwidth.
fn cmd_disagg() -> Result<()> {
    println!("== §4: dis-aggregated tier bandwidth (100 TOP/s device) ==\n");
    let dev = DeviceSpec::fig3(32.0, 10.0);
    println!("{:<28} {:>14} {:>14} {:>12}", "model", "inf/s", "ingress GB/s", "total GB/s");
    for m in zoo_models() {
        let r = disagg_bandwidth(&m, &dev);
        println!(
            "{:<28} {:>14.0} {:>14.3} {:>12.3}",
            r.model,
            r.inferences_per_s,
            r.ingress_bytes_s / 1e9,
            r.total_gbps()
        );
    }
    Ok(())
}

/// §4 co-design directions: design grid x zoo (see bench codesign_sweep).
fn cmd_codesign() -> Result<()> {
    println!("== §4: accelerator design-space sweep (geomean TOP/s per category) ==\n");
    let zoo = representative_zoo();
    let designs = [
        ("compute-heavy", 200e12, 100e9, 16.0),
        ("balanced", 100e12, 100e9, 32.0),
        ("bandwidth-heavy", 50e12, 400e9, 16.0),
        ("capacity-heavy", 100e12, 100e9, 128.0),
    ];
    println!("{:<18} {:>12} {:>12} {:>12}", "design", "recsys", "cv", "nmt");
    for (name, ops, bw, mb) in designs {
        let dev = dcinfer::perfmodel::DeviceSpec {
            name,
            peak_ops: ops,
            dram_bw: bw,
            onchip_capacity: mb * 1e6,
            onchip_bw: 10e12,
            weight_bytes_per_elem: 1.0,
            act_bytes_per_elem: 1.0,
        };
        let mut sums = std::collections::BTreeMap::new();
        for e in &zoo {
            let r = dcinfer::perfmodel::roofline_model(&e.desc, &dev);
            let key = format!("{:?}", e.desc.category);
            let ent = sums.entry(key).or_insert((0.0f64, 0usize));
            ent.0 += (r.achieved_ops / 1e12).ln();
            ent.1 += 1;
        }
        let g = |k: &str| {
            let (s, n) = sums[k];
            (s / n as f64).exp()
        };
        println!(
            "{:<18} {:>12.2} {:>12.2} {:>12.2}",
            name,
            g("Recommendation"),
            g("ComputerVision"),
            g("Language")
        );
    }
    println!("\n(recommendation/NMT want bandwidth; CV wants capacity — no single winner)");
    Ok(())
}

/// Artifacts dir for the serving subcommands: `--artifacts DIR` when
/// given (how mini-fleet members share one fixture), else `artifacts/`
/// when built (`make artifacts`), else a self-synthesized fixture in a
/// temp dir so `serve`/`loadgen` run out of the box. Returns
/// `(dir, is_fixture)` — the fixture (only) is deleted on exit, and an
/// explicit `--artifacts` dir is never treated as a fixture: its owner
/// cleans it up.
fn artifacts_or_fixture(flags: &BTreeMap<String, String>) -> Result<(PathBuf, bool)> {
    if let Some(dir) = flags.get("artifacts") {
        let dir = PathBuf::from(dir);
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "--artifacts {}: no manifest.json there",
            dir.display()
        );
        return Ok((dir, false));
    }
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        return Ok((dir, false));
    }
    let tmp = dcinfer::runtime::synthetic_artifacts_dir("cli")?;
    println!(
        "(no artifacts/manifest.json; using the self-synthesized fixture at {} —\n run `make artifacts` for the real model families)\n",
        tmp.display()
    );
    Ok((tmp, true))
}

/// Build one `ModelService` per comma-separated family name.
fn services_for(manifest: &Manifest, models: &str) -> Result<Vec<Arc<dyn ModelService>>> {
    let mut services: Vec<Arc<dyn ModelService>> = Vec::new();
    for name in models.split(',').filter(|s| !s.is_empty()) {
        let svc: Arc<dyn ModelService> = match name {
            "recsys" => Arc::new(RecSysService::from_manifest(manifest)?),
            "cv" => Arc::new(CvService::from_manifest(manifest)?),
            "nmt" => Arc::new(NmtService::from_manifest(manifest)?),
            other => anyhow::bail!("unknown model {other} (expected recsys, cv, nmt)"),
        };
        services.push(svc);
    }
    Ok(services)
}

/// Run the serving frontend: self-driving synthetic load by default, or
/// the network serving plane with `--listen ADDR`.
fn cmd_serve(flags: &BTreeMap<String, String>) -> Result<()> {
    install_faults_flag(flags)?;
    let n: u64 = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(500);
    let executors = flags.get("executors").and_then(|v| v.parse().ok()).unwrap_or(2);
    let qps: f64 = flags.get("qps").and_then(|v| v.parse().ok()).unwrap_or(2000.0);
    let models = flags.get("models").cloned().unwrap_or_else(|| "recsys".to_string());
    let (art_dir, fixture) = artifacts_or_fixture(flags)?;
    // `--precision` alone implies the native backend (pjrt is fp32-only);
    // the fixture carries native op programs but no compiled HLO, so it
    // defaults to native too
    let mut backend = match (flags.get("backend"), flags.get("precision")) {
        (None, None) if fixture => {
            dcinfer::runtime::BackendSpec::native(dcinfer::runtime::Precision::Fp32)
        }
        (None, None) => dcinfer::runtime::BackendSpec::default(),
        (b, p) => dcinfer::runtime::BackendSpec::from_cli(
            b.map(|s| s.as_str()).unwrap_or("native"),
            p.map(|s| s.as_str()).unwrap_or(""),
        )?,
    };
    // `--threads` fans each GEMM out across an intra-op worker pool
    if let Some(t) = flags.get("threads") {
        let t: usize =
            t.parse().map_err(|_| anyhow::anyhow!("invalid --threads value {t:?}"))?;
        backend = backend.with_threads(t)?;
    }
    // `--sparse-shards` turns on the dis-aggregated sparse tier (§4);
    // malformed values are errors, not silent fallbacks — a typo here
    // would otherwise change which code path gets measured
    let sparse_usize = |key: &str, dflt: usize| -> Result<usize> {
        match flags.get(key) {
            None => Ok(dflt),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("invalid --{key} value {v:?}")),
        }
    };
    let sparse_tier = match flags.get("sparse-shards") {
        None => {
            for key in ["sparse-cache", "sparse-replication", "remote-shards"] {
                anyhow::ensure!(
                    !flags.contains_key(key),
                    "--{key} requires --sparse-shards"
                );
            }
            None
        }
        Some(_) => {
            let default = dcinfer::embedding::SparseTierConfig::default();
            // `--remote-shards a:p,b:p,...` swaps in-process shard
            // threads for standalone `dcinfer shard-serve` processes,
            // one address per shard slot
            let remote_shards: Vec<String> = flags
                .get("remote-shards")
                .map(|v| {
                    v.split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.to_string())
                        .collect()
                })
                .unwrap_or_default();
            Some(dcinfer::embedding::SparseTierConfig {
                shards: sparse_usize("sparse-shards", 0)?,
                replication: sparse_usize("sparse-replication", default.replication)?,
                cache_capacity_rows: sparse_usize("sparse-cache", default.cache_capacity_rows)?,
                remote_shards,
                ..default
            })
        }
    };
    let mode = match flags.get("listen") {
        Some(addr) => format!("listening on {addr}"),
        None => format!("{n} requests @ {qps} offered qps"),
    };
    println!(
        "== serving frontend: {mode}, {executors} executors, models [{models}], backend {} ==\n",
        backend.label()
    );
    if let Some(st) = &sparse_tier {
        let placement = if st.remote_shards.is_empty() {
            "in-process".to_string()
        } else {
            format!("{} remote shard processes", st.remote_shards.len())
        };
        println!(
            "sparse tier: {} shards ({placement}), replication {}, hot-row cache {} rows\n",
            st.shards, st.replication, st.cache_capacity_rows
        );
    }

    // build one service per requested family; each knows its artifact
    // prefix and how to synthesize production-like requests
    let manifest = Manifest::load(&art_dir)?;
    let services = services_for(&manifest, &models)?;

    let mut cfg = FrontendConfig {
        artifacts_dir: art_dir.clone(),
        executors,
        backend,
        sparse_tier,
        ..Default::default()
    };
    if let Some(mq) = flags.get("max-queue") {
        cfg.max_queue_depth =
            mq.parse().map_err(|_| anyhow::anyhow!("invalid --max-queue value {mq:?}"))?;
    }
    let frontend = Arc::new(ServingFrontend::start(cfg, services)?);

    let (wall, submitted, failed) = match flags.get("listen") {
        Some(addr) => {
            let duration: f64 = match flags.get("duration") {
                None => 0.0,
                Some(v) => {
                    v.parse().map_err(|_| anyhow::anyhow!("invalid --duration value {v:?}"))?
                }
            };
            let label = flags.get("replica-label").cloned().unwrap_or_default();
            // the sequence plane rides along whenever the nmt family is
            // served: whole decode loops submitted as one frame, run
            // under step-level continuous batching
            let seq = if frontend.service(NmtService::MODEL_ID).is_some() {
                let mut seq_cfg = SeqConfig {
                    artifacts_dir: art_dir.clone(),
                    backend,
                    ..Default::default()
                };
                if let Some(v) = flags.get("seq-sessions") {
                    seq_cfg.max_sessions = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("invalid --seq-sessions value {v:?}"))?;
                }
                let svc = NmtService::from_manifest(&manifest)?;
                Some(Arc::new(SeqEngine::start(seq_cfg, svc)?))
            } else {
                None
            };
            serve_listen(&frontend, seq, addr, duration, label)?
        }
        None => serve_selfdrive(&frontend, n, qps)?,
    };
    for (model, snap) in frontend.snapshot_all() {
        println!("\n--- {model} ---");
        snap.print();
    }
    if let Some(tier) = frontend.sparse_tier() {
        let s = tier.snapshot();
        println!(
            "\n--- sparse tier ({} shards x{}, cache {} rows) ---",
            s.shards, s.replication, s.cache_capacity_rows
        );
        println!(
            "{} lookups over {} indices, {:.2} MB across the tier boundary (hit rate {:.1}%)",
            s.lookups,
            s.indices,
            s.boundary_bytes() as f64 / 1e6,
            s.hit_rate() * 100.0
        );
        for t in &s.tables {
            println!(
                "  {}: {:.1}% hit rate, {} insertions, {} evictions",
                t.key,
                t.hit_rate() * 100.0,
                t.insertions,
                t.evictions
            );
        }
    }
    println!(
        "\nwall time {wall:.2}s, achieved {:.0} req/s end-to-end, {failed} failed",
        submitted as f64 / wall.max(1e-9)
    );
    frontend.shutdown();
    if fixture {
        let _ = std::fs::remove_dir_all(&art_dir);
    }
    Ok(())
}

/// The self-driving synthetic loop: one process plays both client and
/// server. Sheds (admission control under `--max-queue`) are counted,
/// not fatal — that's the load-shedding contract.
fn serve_selfdrive(
    frontend: &Arc<ServingFrontend>,
    n: u64,
    qps: f64,
) -> Result<(f64, u64, u64)> {
    let lanes: Vec<Arc<dyn ModelService>> =
        frontend.models().iter().map(|m| frontend.service(m).unwrap().clone()).collect();
    let mut rng = Pcg32::seeded(42);
    let gap = Duration::from_secs_f64(1.0 / qps);
    let mut receivers = Vec::with_capacity(n as usize);
    let mut shed = 0u64;
    let t0 = Instant::now();
    for i in 0..n {
        let mut req = lanes[i as usize % lanes.len()].synth_request(i, &mut rng, 0.0);
        req.arrival = Instant::now();
        match frontend.submit(req) {
            Ok(rx) => receivers.push(rx),
            Err(e) => match e.downcast_ref::<InferError>() {
                Some(InferError::Overloaded(_)) => shed += 1,
                _ => return Err(e),
            },
        }
        std::thread::sleep(gap);
    }
    let mut failed = 0u64;
    for rx in receivers {
        if !rx.recv()?.is_ok() {
            failed += 1;
        }
    }
    if shed > 0 {
        println!("{shed} requests shed by admission control");
    }
    Ok((t0.elapsed().as_secs_f64(), n, failed))
}

/// The network mode: a wire-protocol TCP server over the frontend,
/// reporting per-model serving stats every few seconds until
/// `duration_s` elapses (0 = until killed), then draining gracefully.
/// With `seq` set the server also accepts `SeqSubmit` frames and the
/// engine's decode stats print alongside the per-model metrics.
fn serve_listen(
    frontend: &Arc<ServingFrontend>,
    seq: Option<Arc<SeqEngine>>,
    addr: &str,
    duration_s: f64,
    replica_label: String,
) -> Result<(f64, u64, u64)> {
    let cfg = ServerConfig { replica_label, ..Default::default() };
    let server = ServingServer::bind_with_seq(frontend.clone(), seq.clone(), addr, cfg)?;
    println!(
        "listening on {} ({})",
        server.local_addr(),
        if duration_s > 0.0 { format!("for {duration_s:.0}s") } else { "until killed".to_string() }
    );
    let t0 = Instant::now();
    let tick = Duration::from_secs(5);
    loop {
        let elapsed = t0.elapsed().as_secs_f64();
        if duration_s > 0.0 {
            let remaining = duration_s - elapsed;
            if remaining <= 0.0 {
                break;
            }
            std::thread::sleep(tick.min(Duration::from_secs_f64(remaining)));
        } else {
            std::thread::sleep(tick);
        }
        for (model, snap) in frontend.snapshot_all() {
            println!(
                "[{:>5.0}s] {model}: served {} shed {} failed {} depth {} p99 {:.1} ms",
                t0.elapsed().as_secs_f64(),
                snap.served,
                snap.shed,
                snap.failed,
                snap.queue_depth,
                snap.total_p99_us / 1e3
            );
        }
        if let Some(engine) = &seq {
            let s = engine.snapshot();
            println!(
                "[{:>5.0}s] seq: {} live, {} tokens over {} iterations (fill {:.0}%), \
                 {} shed, step cost {:.0} us",
                t0.elapsed().as_secs_f64(),
                s.live,
                s.tokens,
                s.iterations,
                s.mean_fill() * 100.0,
                s.shed,
                s.step_cost_us
            );
        }
    }
    println!("\ndraining {} connections...", server.connections_accepted());
    server.shutdown();
    if let Some(engine) = &seq {
        // after the connection drain every accepted sequence has
        // streamed its Done, so this is the final decode-loop tally
        engine.shutdown();
        let s = engine.snapshot();
        println!("\n--- sequence plane ---");
        println!(
            "{} submitted ({} shed), {} finished on EOS + {} at max-len, {} tokens",
            s.submitted, s.shed, s.done_eos, s.done_maxlen, s.tokens
        );
        println!(
            "{} decode iterations, {:.2} tokens/iteration, batch fill {:.0}%, \
             per-iteration cost {:.0} us",
            s.iterations,
            s.tokens_per_iteration(),
            s.mean_fill() * 100.0,
            s.step_cost_us
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mut served, mut failed) = (0u64, 0u64);
    for (_, snap) in frontend.snapshot_all() {
        served += snap.served;
        failed += snap.failed;
    }
    Ok((wall, served + failed, failed))
}

/// Connect, retrying while the server is still coming up (a loadgen
/// racing `serve --listen` startup — e.g. the CI loopback smoke —
/// should wait, not fail on the first refused connection).
fn connect_with_retry(addr: &str, budget: Duration) -> Result<DcClient> {
    let t0 = Instant::now();
    loop {
        match DcClient::connect(addr) {
            Ok(c) => return Ok(c),
            Err(e) if t0.elapsed() < budget => {
                println!("waiting for {addr} ({e:#})");
                std::thread::sleep(Duration::from_millis(500));
            }
            Err(e) => {
                return Err(e.context(format!(
                    "connecting to {addr} (is `dcinfer serve --listen` up?)"
                )))
            }
        }
    }
}

/// Open-loop load generator against a remote `serve --listen`: Poisson
/// arrivals at `--qps` over a weighted `--mix` of model families,
/// reporting latency percentiles, goodput and the shed rate. With
/// `--seq DIST` it drives the sequence plane instead (see
/// [`loadgen_seq`]).
fn cmd_loadgen(flags: &BTreeMap<String, String>) -> Result<()> {
    install_faults_flag(flags)?;
    if let Some(dist) = flags.get("seq") {
        return loadgen_seq(flags, dist);
    }
    let addr = flags.get("connect").context("--connect ADDR is required")?;
    let qps: f64 = flags.get("qps").and_then(|v| v.parse().ok()).unwrap_or(1000.0);
    anyhow::ensure!(qps > 0.0, "--qps must be positive");
    let n: u64 = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(2000);
    let mix = flags.get("mix").cloned().unwrap_or_else(|| "recsys:1".to_string());
    let seed: u64 = flags.get("seed").and_then(|v| v.parse().ok()).unwrap_or(42);
    let deadline_override: Option<f64> = match flags.get("deadline-ms") {
        None => None,
        Some(v) => Some(
            v.parse().map_err(|_| anyhow::anyhow!("invalid --deadline-ms value {v:?}"))?,
        ),
    };
    // `--demand` modulates the open-loop arrival rate along a replayed
    // day (Fig 1); `--demand-period` compresses that day into wall
    // seconds. `--skew` redraws embedding indices from a Zipf so the
    // sparse tier sees production-like hot rows.
    let demand = match flags.get("demand") {
        None => DemandCurve::Constant,
        Some(spec) => DemandCurve::parse(spec).context("--demand")?,
    };
    let demand_period: f64 =
        flags.get("demand-period").and_then(|v| v.parse().ok()).unwrap_or(60.0);
    anyhow::ensure!(demand_period > 0.0, "--demand-period must be positive");
    let skew: Option<IndexSkew> = match flags.get("skew") {
        None => None,
        Some(spec) => Some(IndexSkew::parse(spec).context("--skew")?),
    };

    // request synthesis needs the families' dimensions — they must
    // describe the same artifact set the server loaded
    let (art_dir, fixture) = artifacts_or_fixture(flags)?;
    let manifest = Manifest::load(&art_dir)?;
    let mut arms: Vec<(Arc<dyn ModelService>, f64)> = Vec::new();
    for part in mix.split(',').filter(|s| !s.is_empty()) {
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => {
                let w: f64 = w
                    .parse()
                    .map_err(|_| anyhow::anyhow!("invalid mix weight in {part:?}"))?;
                (n, w)
            }
            None => (part, 1.0),
        };
        anyhow::ensure!(weight > 0.0, "mix weight in {part:?} must be positive");
        anyhow::ensure!(!name.is_empty(), "empty model name in mix entry {part:?}");
        let svc = services_for(&manifest, name)?.remove(0);
        anyhow::ensure!(
            !arms.iter().any(|(s, _)| s.model_id() == svc.model_id()),
            "duplicate mix entry for {name}"
        );
        arms.push((svc, weight));
    }
    anyhow::ensure!(!arms.is_empty(), "--mix selected no models");
    let weights: Vec<f64> = arms.iter().map(|(_, w)| *w).collect();

    let client = connect_with_retry(addr, Duration::from_secs(30))?;
    let shape = match (&demand, skew) {
        (DemandCurve::Constant, None) => String::new(),
        _ => {
            let mut parts = Vec::new();
            if demand != DemandCurve::Constant {
                parts.push(format!("demand-modulated over {demand_period:.0}s/day"));
            }
            if let Some(s) = skew {
                parts.push(format!("index skew {s:?}"));
            }
            format!(", {}", parts.join(", "))
        }
    };
    println!(
        "== loadgen: {n} arrivals @ {qps} qps (open-loop Poisson{shape}) \
         against {addr}, mix [{mix}] ==\n"
    );

    // open loop: the arrival schedule never waits on responses — late
    // responses pile up in flight exactly like real overload. With a
    // demand curve the process is inhomogeneous Poisson via thinning:
    // candidates arrive at the envelope rate `qps * demand.max()` and
    // each survives with probability multiplier(phase)/max, so the
    // instantaneous rate is qps * multiplier(phase of the replayed day)
    let envelope = demand.max();
    let mut rng = Pcg32::seeded(seed);
    let mut pending: Vec<(String, f64, std::sync::mpsc::Receiver<ClientResponse>)> =
        Vec::with_capacity(n as usize);
    let mut send_errors = 0u64;
    let t0 = Instant::now();
    let mut next_at = 0.0f64;
    for i in 0..n {
        next_at += rng.exponential(qps * envelope);
        if demand != DemandCurve::Constant {
            let phase = next_at / demand_period;
            if rng.uniform() >= demand.multiplier(phase) / envelope {
                continue; // thinned: this candidate falls outside the curve
            }
        }
        let now = t0.elapsed().as_secs_f64();
        if next_at > now {
            std::thread::sleep(Duration::from_secs_f64(next_at - now));
        }
        let svc = &arms[rng.weighted_choice(&weights)].0;
        let deadline =
            deadline_override.unwrap_or_else(|| svc.deadline_class().default_deadline_ms());
        let req = match skew {
            None => svc.synth_request(i, &mut rng, deadline),
            Some(s) => svc.synth_request_skewed(i, &mut rng, deadline, s),
        };
        match client.submit(&req) {
            Ok(rx) => pending.push((req.model.clone(), next_at, rx)),
            Err(_) => send_errors += 1,
        }
    }
    let send_wall = t0.elapsed().as_secs_f64();

    #[derive(Default)]
    struct Agg {
        sent: u64,
        ok: u64,
        shed: u64,
        errs: u64,
        good: u64,
        /// ok responses carrying the degraded flag (stale/zero sparse
        /// contributions served while a row range was unreachable)
        degraded: u64,
        rtt_ms: Samples,
    }
    let mut per_model: BTreeMap<String, Agg> = BTreeMap::new();
    // responses-by-replica: populated when servers stamp
    // `--replica-label` into their responses (a fleet behind a
    // ClusterRouter) — the view that makes failover visible from the
    // client side
    let mut per_replica: BTreeMap<String, u64> = BTreeMap::new();
    let mut all_rtt = Samples::new();
    // the per-interval timeline: responses bucketed by *send* time, so
    // each row reads as "what the server did to traffic offered then"
    const TIMELINE_BUCKETS: usize = 8;
    #[derive(Default)]
    struct Slot {
        offered: u64,
        ok: u64,
        good: u64,
        shed: u64,
        errs: u64,
        rtt_ms: Samples,
    }
    let bucket_w = (send_wall / TIMELINE_BUCKETS as f64).max(1e-9);
    let mut timeline: Vec<Slot> = (0..TIMELINE_BUCKETS).map(|_| Slot::default()).collect();
    for (model, sent_at, rx) in pending {
        let agg = per_model.entry(model).or_default();
        agg.sent += 1;
        let slot =
            &mut timeline[((sent_at / bucket_w) as usize).min(TIMELINE_BUCKETS - 1)];
        slot.offered += 1;
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(cr) => {
                if !cr.resp.replica.is_empty() {
                    *per_replica.entry(cr.resp.replica.clone()).or_default() += 1;
                }
                if cr.shed() {
                    agg.shed += 1;
                    slot.shed += 1;
                } else if cr.resp.is_ok() {
                    agg.ok += 1;
                    slot.ok += 1;
                    if cr.resp.degraded {
                        agg.degraded += 1;
                    }
                    agg.rtt_ms.push(cr.rtt_us / 1e3);
                    all_rtt.push(cr.rtt_us / 1e3);
                    slot.rtt_ms.push(cr.rtt_us / 1e3);
                    if cr.good() {
                        agg.good += 1;
                        slot.good += 1;
                    }
                } else {
                    agg.errs += 1;
                    slot.errs += 1;
                }
            }
            Err(_) => {
                agg.errs += 1;
                slot.errs += 1;
            }
        }
    }
    client.close();

    let mut table = dcinfer::util::bench::Table::new(&[
        "model", "sent", "ok", "shed", "err", "degr", "goodput", "p50 ms", "p99 ms", "p999 ms",
    ]);
    let mut tot = Agg::default();
    // which arm drives the overall tail: the model whose own p99 is
    // largest (ties to the first); printed under the table so mixed-
    // workload runs attribute their aggregate p99 at a glance
    let mut tail_driver: Option<(String, f64)> = None;
    for (model, agg) in per_model.iter_mut() {
        let p99 = agg.rtt_ms.p99();
        let worst = tail_driver.as_ref().map(|(_, w)| *w);
        if agg.ok > 0 && worst.unwrap_or(f64::NEG_INFINITY) < p99 {
            tail_driver = Some((model.clone(), p99));
        }
        table.row(&[
            model.clone(),
            agg.sent.to_string(),
            agg.ok.to_string(),
            agg.shed.to_string(),
            agg.errs.to_string(),
            agg.degraded.to_string(),
            format!("{:.1}%", agg.good as f64 / agg.sent.max(1) as f64 * 100.0),
            format!("{:.2}", agg.rtt_ms.p50()),
            format!("{:.2}", p99),
            format!("{:.2}", agg.rtt_ms.p999()),
        ]);
        tot.sent += agg.sent;
        tot.ok += agg.ok;
        tot.shed += agg.shed;
        tot.errs += agg.errs;
        tot.good += agg.good;
        tot.degraded += agg.degraded;
    }
    if per_model.len() > 1 {
        table.row(&[
            "(all)".to_string(),
            tot.sent.to_string(),
            tot.ok.to_string(),
            tot.shed.to_string(),
            tot.errs.to_string(),
            tot.degraded.to_string(),
            format!("{:.1}%", tot.good as f64 / tot.sent.max(1) as f64 * 100.0),
            format!("{:.2}", all_rtt.p50()),
            format!("{:.2}", all_rtt.p99()),
            format!("{:.2}", all_rtt.p999()),
        ]);
    }
    table.print();
    if per_model.len() > 1 {
        if let Some((model, p99)) = &tail_driver {
            println!("\ntail driver: {model} (p99 {p99:.2} ms)");
        }
    }
    println!(
        "\noffered {qps:.0} qps, achieved send rate {:.0} qps over {send_wall:.2}s",
        n as f64 / send_wall.max(1e-9)
    );
    println!(
        "overall: {}/{} ok, goodput {:.1}%, shed rate {:.1}%, {} errors, {} degraded, \
         {} send failures",
        tot.ok,
        tot.sent,
        tot.good as f64 / tot.sent.max(1) as f64 * 100.0,
        tot.shed as f64 / tot.sent.max(1) as f64 * 100.0,
        tot.errs,
        tot.degraded,
        send_errors
    );
    if tot.sent > 0 {
        let mut tl = dcinfer::util::bench::Table::new(&[
            "interval", "offered qps", "ok", "goodput", "shed", "err", "p99 ms",
        ]);
        for (i, s) in timeline.iter_mut().enumerate() {
            tl.row(&[
                format!("{:>5.1}-{:>5.1}s", i as f64 * bucket_w, (i + 1) as f64 * bucket_w),
                format!("{:.0}", s.offered as f64 / bucket_w),
                s.ok.to_string(),
                format!("{:.1}%", s.good as f64 / s.offered.max(1) as f64 * 100.0),
                s.shed.to_string(),
                s.errs.to_string(),
                format!("{:.2}", s.rtt_ms.p99()),
            ]);
        }
        println!("\n--- timeline ({TIMELINE_BUCKETS} intervals by send time) ---");
        tl.print();
    }
    if !per_replica.is_empty() {
        let answered: u64 = per_replica.values().sum();
        println!("\nresponses by serving replica:");
        for (replica, count) in &per_replica {
            println!(
                "  {replica}: {count} ({:.1}%)",
                *count as f64 / answered.max(1) as f64 * 100.0
            );
        }
    }
    if fixture {
        let _ = std::fs::remove_dir_all(&art_dir);
    }
    anyhow::ensure!(tot.ok > 0, "no successful responses — is the server serving this mix?");
    Ok(())
}

/// The sequence-plane load generator (`loadgen --seq DIST`): one
/// `SeqSubmit` per sequence, open-loop Poisson arrivals at `--qps`
/// sequences/second, output lengths drawn from `DIST` — the
/// mixed-length regime continuous batching exists for (short
/// sequences exit on EOS and free their slot mid-flight). Reports
/// tokens/sec plus the streaming latency set: time-to-first-token,
/// inter-token gap, per-token and whole-sequence percentiles.
fn loadgen_seq(flags: &BTreeMap<String, String>, dist: &str) -> Result<()> {
    let addr = flags.get("connect").context("--connect ADDR is required")?;
    let length_dist = LengthDistribution::parse(dist).context("--seq")?;
    let qps: f64 = flags.get("qps").and_then(|v| v.parse().ok()).unwrap_or(200.0);
    anyhow::ensure!(qps > 0.0, "--qps must be positive");
    let n: u64 = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(200);
    anyhow::ensure!(n > 0, "--requests must be positive");
    let seed: u64 = flags.get("seed").and_then(|v| v.parse().ok()).unwrap_or(42);
    let max_len: u32 = match flags.get("max-len") {
        None => 64,
        Some(v) => {
            v.parse().map_err(|_| anyhow::anyhow!("invalid --max-len value {v:?}"))?
        }
    };
    anyhow::ensure!(max_len >= 1, "--max-len must be >= 1");
    // a sequence deadline covers the whole decode loop (it gates the
    // server's length-aware admission); 0 = none, nothing is shed
    let deadline_ms: f64 = match flags.get("deadline-ms") {
        None => 0.0,
        Some(v) => {
            v.parse().map_err(|_| anyhow::anyhow!("invalid --deadline-ms value {v:?}"))?
        }
    };

    let (art_dir, fixture) = artifacts_or_fixture(flags)?;
    let manifest = Manifest::load(&art_dir)?;
    let svc = NmtService::from_manifest(&manifest)?;
    let client = connect_with_retry(addr, Duration::from_secs(30))?;
    println!(
        "== loadgen --seq: {n} sequences @ {qps} seq/s against {addr}, \
         lengths {dist} (mean {:.1}, cap {max_len}) ==\n",
        length_dist.mean()
    );

    let mut rng = Pcg32::seeded(seed);
    let mut pending = Vec::with_capacity(n as usize);
    let mut send_errors = 0u64;
    let t0 = Instant::now();
    let mut next_at = 0.0f64;
    for i in 0..n {
        next_at += rng.exponential(qps);
        let now = t0.elapsed().as_secs_f64();
        if next_at > now {
            std::thread::sleep(Duration::from_secs_f64(next_at - now));
        }
        let len = length_dist.sample(&mut rng, max_len);
        let (x0, h0) = svc.synth_seq_state(i, seed);
        let req = svc.seq_request(i, x0, h0, len, deadline_ms)?;
        match client.submit_seq(&req) {
            Ok(stream) => pending.push(stream),
            Err(_) => send_errors += 1,
        }
    }
    let send_wall = t0.elapsed().as_secs_f64();

    // drain the streams; every token's rtt was stamped by the client's
    // reader thread at receipt, so sequential draining here does not
    // skew the latency samples
    let mut ttft = Samples::new();
    let mut gap = Samples::new();
    let mut per_tok = Samples::new();
    let mut seq_ms = Samples::new();
    let (mut eos, mut maxlen, mut shed, mut errs, mut good) = (0u64, 0u64, 0u64, 0u64, 0u64);
    let mut tokens = 0u64;
    for stream in pending {
        let mut prev_rtt = 0.0f64;
        let mut finished = false;
        while let Some(ev) = stream.recv() {
            match ev {
                SeqClientEvent::Token { step, rtt_us, .. } => {
                    tokens += 1;
                    if step <= 1 {
                        ttft.push(rtt_us / 1e3);
                    } else {
                        gap.push((rtt_us - prev_rtt) / 1e3);
                    }
                    prev_rtt = rtt_us;
                }
                SeqClientEvent::Done { done, rtt_us } => {
                    finished = true;
                    match done.outcome {
                        Ok(fin) => {
                            match fin {
                                SeqFinish::Eos => eos += 1,
                                SeqFinish::MaxLen => maxlen += 1,
                            }
                            if done.steps > 0 {
                                per_tok.push(rtt_us / 1e3 / f64::from(done.steps));
                            }
                            seq_ms.push(rtt_us / 1e3);
                            if deadline_ms <= 0.0 || rtt_us / 1e3 <= deadline_ms {
                                good += 1;
                            }
                        }
                        Err(InferError::Overloaded(_)) => shed += 1,
                        Err(_) => errs += 1,
                    }
                }
            }
        }
        if !finished {
            // stream closed without a terminal frame (connection died)
            errs += 1;
        }
    }
    client.close();
    let wall = t0.elapsed().as_secs_f64();

    let sent = n - send_errors;
    println!(
        "sequences: {sent} sent, {eos} finished on EOS, {maxlen} at max-len, \
         {shed} shed, {errs} errors, {send_errors} send failures"
    );
    println!(
        "goodput {:.1}% (completed{}), achieved send rate {:.0} seq/s over {send_wall:.2}s",
        good as f64 / sent.max(1) as f64 * 100.0,
        if deadline_ms > 0.0 { " within deadline" } else { "" },
        sent as f64 / send_wall.max(1e-9)
    );
    println!(
        "{tokens} tokens in {wall:.2}s -> {:.0} tokens/sec",
        tokens as f64 / wall.max(1e-9)
    );
    println!(
        "TTFT p50/p99 {:.2}/{:.2} ms, inter-token p50/p99 {:.2}/{:.2} ms, \
         per-token p99 {:.3} ms, sequence p50/p99 {:.2}/{:.2} ms",
        ttft.p50(),
        ttft.p99(),
        gap.p50(),
        gap.p99(),
        per_tok.p99(),
        seq_ms.p50(),
        seq_ms.p99()
    );
    if fixture {
        let _ = std::fs::remove_dir_all(&art_dir);
    }
    anyhow::ensure!(
        eos + maxlen > 0,
        "no sequences completed — is the sequence plane up (serve --listen with nmt in --models)?"
    );
    Ok(())
}

/// One standalone embedding-shard server (§4 dis-aggregation as a real
/// process): an empty `ShardStore` behind the wire protocol's shard
/// frames, populated by whichever serving replicas register tables into
/// it. Runs until killed — fleet members are processes precisely so a
/// `kill` is a meaningful failure experiment.
fn cmd_shard_serve(flags: &BTreeMap<String, String>) -> Result<()> {
    install_faults_flag(flags)?;
    let addr = flags.get("listen").map(|s| s.as_str()).unwrap_or("127.0.0.1:0");
    let server = ShardServer::bind(addr, ShardServerConfig::default())?;
    // machine-readable: `ChildProc::spawn` parses this line to learn
    // the ephemeral port when launched with `--listen 127.0.0.1:0`
    println!("listening on {} (embedding shard server, until killed)", server.local_addr());
    let mut last_ops = 0u64;
    loop {
        std::thread::sleep(Duration::from_secs(5));
        let s = server.stats();
        if s.ops != last_ops {
            println!(
                "{} tables, {} ops, {:.2} MB in / {:.2} MB out across the boundary",
                server.table_count(),
                s.ops,
                s.ingress_bytes as f64 / 1e6,
                s.egress_bytes as f64 / 1e6
            );
            last_ops = s.ops;
        }
    }
}

/// The loopback mini-fleet: M `shard-serve` processes, N `serve
/// --listen` replicas wired to them over `--remote-shards`, one
/// `ClusterRouter` in front, loadgen driven through the router, and
/// the per-replica fleet view printed at the end.
fn cmd_cluster(flags: &BTreeMap<String, String>) -> Result<()> {
    install_faults_flag(flags)?;
    let replicas: usize = flags.get("replicas").and_then(|v| v.parse().ok()).unwrap_or(2);
    let shard_procs: usize =
        flags.get("shard-procs").and_then(|v| v.parse().ok()).unwrap_or(2);
    anyhow::ensure!(replicas >= 1, "--replicas must be at least 1");
    let replication: usize = flags
        .get("sparse-replication")
        .and_then(|v| v.parse().ok())
        .unwrap_or(if shard_procs >= 2 { 2 } else { 1 });
    if shard_procs > 0 {
        anyhow::ensure!(
            replication >= 1 && shard_procs % replication == 0,
            "--shard-procs ({shard_procs}) must be a multiple of \
             --sparse-replication ({replication})"
        );
    }
    let n: u64 = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(400);
    let qps: f64 = flags.get("qps").and_then(|v| v.parse().ok()).unwrap_or(800.0);
    let mix = flags.get("mix").cloned().unwrap_or_else(|| "recsys:1".to_string());
    let seed: u64 = flags.get("seed").and_then(|v| v.parse().ok()).unwrap_or(42);
    // the serving replicas must load every family the mix exercises
    let models: String = mix
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|p| p.split_once(':').map(|(name, _)| name).unwrap_or(p))
        .collect::<Vec<_>>()
        .join(",");

    let bin = std::env::current_exe().context("resolving the dcinfer binary path")?;
    // every fleet member must load the *same* artifact set — share one
    // dir via --artifacts instead of letting each child synthesize
    let (art_dir, fixture) = artifacts_or_fixture(flags)?;
    let art = art_dir.to_string_lossy().to_string();

    println!(
        "== cluster: {replicas} serving replicas, {shard_procs} shard processes \
         (x{replication} replication), mix [{mix}] ==\n"
    );

    // the same fault spec goes to every child: each process's streams
    // match it by peer label, so one flag chaos-tests the whole fleet
    let faults = flags.get("faults").cloned();
    let mut shard_children: Vec<ChildProc> = Vec::new();
    for s in 0..shard_procs {
        let mut sargs = vec!["shard-serve", "--listen", "127.0.0.1:0"];
        if let Some(f) = &faults {
            sargs.extend_from_slice(&["--faults", f.as_str()]);
        }
        shard_children.push(ChildProc::spawn(&bin, &sargs, &format!("shard-{s}"))?);
    }
    let shard_addrs =
        shard_children.iter().map(|c| c.addr.clone()).collect::<Vec<_>>().join(",");

    // the sparse tier dis-aggregates *native* lanes (pjrt executes HLO
    // with tables baked in), so the fleet defaults to the native
    // backend; `--backend`/`--precision` still pass through
    let backend = flags.get("backend").cloned().unwrap_or_else(|| "native".to_string());
    let mut serve_children: Vec<ChildProc> = Vec::new();
    for r in 0..replicas {
        let label = format!("replica-{r}");
        let shards_s = shard_procs.to_string();
        let repl_s = replication.to_string();
        let mut args = vec![
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--models",
            &models,
            "--artifacts",
            &art,
            "--backend",
            &backend,
            "--replica-label",
            &label,
        ];
        if let Some(p) = flags.get("precision") {
            args.extend_from_slice(&["--precision", p.as_str()]);
        }
        if shard_procs > 0 {
            args.extend_from_slice(&[
                "--sparse-shards",
                &shards_s,
                "--sparse-replication",
                &repl_s,
                "--remote-shards",
                &shard_addrs,
            ]);
        }
        if let Some(f) = &faults {
            args.extend_from_slice(&["--faults", f.as_str()]);
        }
        serve_children.push(ChildProc::spawn(&bin, &args, &label)?);
    }

    let replica_addrs: Vec<String> = serve_children.iter().map(|c| c.addr.clone()).collect();
    let router = ClusterRouter::bind("127.0.0.1:0", &replica_addrs, RouterConfig::default())?;
    println!("listening on {} (cluster router over {replicas} replicas)\n", router.local_addr());

    let mut lg: BTreeMap<String, String> = BTreeMap::new();
    lg.insert("connect".into(), router.local_addr().to_string());
    lg.insert("qps".into(), qps.to_string());
    lg.insert("requests".into(), n.to_string());
    lg.insert("mix".into(), mix.clone());
    lg.insert("seed".into(), seed.to_string());
    lg.insert("artifacts".into(), art.clone());
    let lg_result = cmd_loadgen(&lg);

    println!("\n--- fleet (router view) ---");
    let mut table = dcinfer::util::bench::Table::new(&[
        "replica", "state", "sent", "done", "failed", "trips", "inflight", "p50 ms", "p99 ms",
    ]);
    for (i, s) in router.stats().iter().enumerate() {
        let state = if s.retired {
            "retired"
        } else if !s.healthy {
            "down"
        } else if s.suspect {
            "suspect"
        } else {
            "healthy"
        };
        table.row(&[
            format!("replica-{i} ({})", s.addr),
            state.to_string(),
            s.sent.to_string(),
            s.completed.to_string(),
            s.failed.to_string(),
            s.breaker_trips.to_string(),
            s.inflight.to_string(),
            format!("{:.2}", s.p50_ms),
            format!("{:.2}", s.p99_ms),
        ]);
    }
    table.print();

    router.shutdown();
    drop(serve_children);
    drop(shard_children);
    if fixture {
        let _ = std::fs::remove_dir_all(&art_dir);
    }
    lg_result
}

/// Closed-loop elastic capacity through a simulated peak: a loopback
/// serving tier starts at `--min-executors`, a demand-replayed loadgen
/// (one simulated day compressed into `--period` seconds, peaking
/// mid-run) drives it past what that capacity can carry, and an
/// [`AutoscaleController`] polling the serving metrics every
/// `--interval-ms` resizes the live executor pool — up into the peak,
/// back down after the trough. Prints every scale decision and the
/// shed/SLO summary.
fn cmd_autoscale(flags: &BTreeMap<String, String>) -> Result<()> {
    install_faults_flag(flags)?;
    let n: u64 = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(4000);
    let peak_qps: f64 = flags.get("peak-qps").and_then(|v| v.parse().ok()).unwrap_or(1200.0);
    anyhow::ensure!(peak_qps > 0.0, "--peak-qps must be positive");
    let period: f64 = flags.get("period").and_then(|v| v.parse().ok()).unwrap_or(16.0);
    anyhow::ensure!(period > 0.0, "--period must be positive");
    let min_cap: usize = flags.get("min-executors").and_then(|v| v.parse().ok()).unwrap_or(1);
    let max_cap: usize = flags.get("max-executors").and_then(|v| v.parse().ok()).unwrap_or(6);
    let interval_ms: u64 =
        flags.get("interval-ms").and_then(|v| v.parse().ok()).unwrap_or(400);
    anyhow::ensure!(interval_ms >= 1, "--interval-ms must be at least 1");
    let seed: u64 = flags.get("seed").and_then(|v| v.parse().ok()).unwrap_or(42);
    let models = flags.get("models").cloned().unwrap_or_else(|| "recsys".to_string());
    // peak_hour=12 puts the crest mid-run: the run starts in the
    // trough, climbs through the peak, and ends back in the trough —
    // one full scale-up/scale-down episode per invocation
    let demand_spec = flags
        .get("demand")
        .cloned()
        .unwrap_or_else(|| "diurnal:peak=1.0,trough=0.15,peak_hour=12".to_string());
    let demand = DemandCurve::parse(&demand_spec).context("--demand")?;
    let skew = IndexSkew::parse(flags.get("skew").map(|s| s.as_str()).unwrap_or("zipf:1.0"))
        .context("--skew")?;

    let (art_dir, fixture) = artifacts_or_fixture(flags)?;
    let manifest = Manifest::load(&art_dir)?;
    let services = services_for(&manifest, &models)?;
    let svcs: Vec<Arc<dyn ModelService>> = services.clone();
    let backend =
        dcinfer::runtime::BackendSpec::native(dcinfer::runtime::Precision::Fp32);
    let frontend = Arc::new(ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: art_dir.clone(),
            executors: min_cap,
            backend,
            ..Default::default()
        },
        services,
    )?);
    let server = ServingServer::bind_with_seq(
        frontend.clone(),
        None,
        "127.0.0.1:0",
        ServerConfig::default(),
    )?;
    println!(
        "== autoscale: loopback tier on {} at {min_cap} executors (max {max_cap}), \
         {n} arrivals peaking at {peak_qps:.0} qps over a {period:.0}s day \
         [{demand_spec}], controller tick {interval_ms} ms ==\n",
        server.local_addr()
    );

    let policy = ScalePolicy {
        min_capacity: min_cap,
        max_capacity: max_cap,
        ..ScalePolicy::default()
    };
    let controller = AutoscaleController::spawn(
        frontend.clone(),
        policy,
        Duration::from_millis(interval_ms),
    )?;

    // the same inhomogeneous-Poisson replay loadgen runs, driving the
    // wire path the controller's metrics watch
    let client = connect_with_retry(&server.local_addr().to_string(), Duration::from_secs(10))?;
    let envelope = demand.max();
    let mut rng = Pcg32::seeded(seed);
    let mut pending = Vec::with_capacity(n as usize);
    let t0 = Instant::now();
    let mut next_at = 0.0f64;
    let mut send_errors = 0u64;
    for i in 0..n {
        next_at += rng.exponential(peak_qps * envelope);
        let phase = next_at / period;
        if rng.uniform() >= demand.multiplier(phase) / envelope {
            continue;
        }
        let now = t0.elapsed().as_secs_f64();
        if next_at > now {
            std::thread::sleep(Duration::from_secs_f64(next_at - now));
        }
        let svc = &svcs[i as usize % svcs.len()];
        let deadline = svc.deadline_class().default_deadline_ms();
        let req = svc.synth_request_skewed(i, &mut rng, deadline, skew);
        match client.submit(&req) {
            Ok(rx) => pending.push((next_at, rx)),
            Err(_) => send_errors += 1,
        }
    }
    let send_wall = t0.elapsed().as_secs_f64();

    // the peak window: the middle third of the replayed day
    let peak_window = (period / 3.0)..(2.0 * period / 3.0);
    let (mut sent, mut ok, mut good, mut shed, mut errs) = (0u64, 0u64, 0u64, 0u64, 0u64);
    let (mut peak_sent, mut peak_shed) = (0u64, 0u64);
    let mut rtt = Samples::new();
    for (sent_at, rx) in pending {
        sent += 1;
        let in_peak = peak_window.contains(&sent_at);
        if in_peak {
            peak_sent += 1;
        }
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(cr) => {
                if cr.shed() {
                    shed += 1;
                    if in_peak {
                        peak_shed += 1;
                    }
                } else if cr.resp.is_ok() {
                    ok += 1;
                    rtt.push(cr.rtt_us / 1e3);
                    if cr.good() {
                        good += 1;
                    }
                } else {
                    errs += 1;
                }
            }
            Err(_) => errs += 1,
        }
    }
    client.close();
    let log = controller.stop();
    server.shutdown();

    let events = format_events(&log);
    println!("--- scale events ({} over {} ticks) ---", events.len(), log.len());
    if events.is_empty() {
        println!("(none — capacity never needed to move)");
    }
    for e in &events {
        println!("{e}");
    }
    let peak_capacity =
        log.iter().map(|d| d.to).chain([min_cap]).max().unwrap_or(min_cap);
    println!("\n--- summary ---");
    println!(
        "{sent} sent over {send_wall:.1}s, {ok} ok, {shed} shed ({:.1}%), {errs} errors, \
         {send_errors} send failures",
        shed as f64 / sent.max(1) as f64 * 100.0
    );
    println!(
        "peak window ({:.1}-{:.1}s): {peak_sent} sent, {peak_shed} shed ({:.1}%)",
        peak_window.start,
        peak_window.end,
        peak_shed as f64 / peak_sent.max(1) as f64 * 100.0
    );
    println!(
        "SLO attainment {:.1}% (answered within deadline), p50/p99 {:.2}/{:.2} ms",
        good as f64 / sent.max(1) as f64 * 100.0,
        rtt.p50(),
        rtt.p99()
    );
    println!(
        "capacity: started {min_cap}, peaked {peak_capacity}, ended {}",
        frontend.executor_capacity()
    );
    frontend.shutdown();
    if fixture {
        let _ = std::fs::remove_dir_all(&art_dir);
    }
    anyhow::ensure!(ok > 0, "no successful responses through the autoscaled tier");
    Ok(())
}
