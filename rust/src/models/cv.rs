//! Computer-vision model descriptors (§2.1.2).
//!
//! - [`resnet50`]: the classification baseline (25M params, ~8 GFLOPs).
//! - [`resnext101`]: ResNeXt-101-32xNd — group convolutions with G=32 and
//!   bottleneck width d; d=4 gives 43M params / 8B MACs, d=48 gives 829M
//!   params / 153B MACs (paper numbers).
//! - [`faster_rcnn_shuffle`]: the Rosetta text detector — ShuffleNet
//!   trunk at 800x600 input plus a proposal-batched detection head.
//! - [`resnext3d_101`]: video model with the channel/spatiotemporal
//!   factorization (97.1% of FLOPs in 1x1x1 convolutions).

use super::{
    conv2d, conv3d, elementwise, fc, pool, softmax, tensor_manip, Category, LatencyClass, Layer,
    ModelDesc,
};

/// Bottleneck residual block (ResNet / ResNeXt): 1x1 down, 3x3 (grouped),
/// 1x1 up, (+ projection on the first block of a stage).
#[allow(clippy::too_many_arguments)]
fn bottleneck(
    layers: &mut Vec<Layer>,
    prefix: &str,
    b: u64,
    ci: u64,
    h: u64,
    w: u64,
    inner: u64,
    co: u64,
    stride: u64,
    groups: u64,
) -> (u64, u64) {
    let (l1, _) = conv2d(&format!("{prefix}.conv1_1x1"), b, ci, h, w, inner, 1, 1, 1, 1);
    layers.push(l1);
    layers.push(elementwise(&format!("{prefix}.relu1"), b * inner * h * w));
    let (l2, (h2, w2)) =
        conv2d(&format!("{prefix}.conv2_3x3"), b, inner, h, w, inner, 3, 3, stride, groups);
    layers.push(l2);
    layers.push(elementwise(&format!("{prefix}.relu2"), b * inner * h2 * w2));
    let (l3, _) = conv2d(&format!("{prefix}.conv3_1x1"), b, inner, h2, w2, co, 1, 1, 1, 1);
    layers.push(l3);
    if stride != 1 || ci != co {
        let (proj, _) =
            conv2d(&format!("{prefix}.proj_1x1"), b, ci, h, w, co, 1, 1, stride, 1);
        layers.push(proj);
    }
    layers.push(elementwise(&format!("{prefix}.add_relu"), b * co * h2 * w2));
    (h2, w2)
}

fn resnet_like(
    name: &str,
    b: u64,
    blocks: [u64; 4],
    inner_base: u64,
    groups: u64,
) -> ModelDesc {
    let mut layers = Vec::new();
    let (stem, (mut h, mut w)) = conv2d("stem.conv7x7", b, 3, 224, 224, 64, 7, 7, 2, 1);
    layers.push(stem);
    layers.push(pool("stem.maxpool", b * 64 * h * w, b * 64 * (h / 2) * (w / 2)));
    h /= 2;
    w /= 2;

    let mut ci = 64u64;
    for (s, &n_blocks) in blocks.iter().enumerate() {
        let inner = inner_base << s;
        let co = 256u64 << s;
        for blk in 0..n_blocks {
            let stride = if s > 0 && blk == 0 { 2 } else { 1 };
            let (h2, w2) = bottleneck(
                &mut layers,
                &format!("stage{}.block{}", s + 1, blk),
                b,
                ci,
                h,
                w,
                inner,
                co,
                stride,
                groups,
            );
            h = h2;
            w = w2;
            ci = co;
        }
    }
    layers.push(pool("head.avgpool", b * ci * h * w, b * ci));
    layers.push(fc("head.fc1000", b, 1000, ci));
    layers.push(softmax("head.softmax", b * 1000));
    ModelDesc {
        name: name.to_string(),
        category: Category::ComputerVision,
        batch: b,
        layers,
        latency: LatencyClass::Relaxed,
    }
}

/// ResNet-50 at 224x224 (per-image descriptor; Table-1 row 3).
pub fn resnet50(batch: u64) -> ModelDesc {
    resnet_like("resnet50", batch, [3, 4, 6, 3], 64, 1)
}

/// ResNeXt-101-32xNd: `d` is the bottleneck width per group (4 or 48).
pub fn resnext101(batch: u64, d: u64) -> ModelDesc {
    let name = format!("resnext101_32x{d}d");
    resnet_like(&name, batch, [3, 4, 23, 3], 32 * d, 32)
}

/// ShuffleNet unit (g=4): 1x1 group conv -> channel shuffle -> 3x3
/// depth-wise -> 1x1 group conv (+ residual / concat on stride 2).
fn shuffle_unit(
    layers: &mut Vec<Layer>,
    prefix: &str,
    b: u64,
    ci: u64,
    h: u64,
    w: u64,
    co: u64,
    stride: u64,
    g: u64,
) -> (u64, u64) {
    let mid = co / 4;
    let (l1, _) = conv2d(&format!("{prefix}.gconv1_1x1"), b, ci, h, w, mid, 1, 1, 1, g);
    layers.push(l1);
    layers.push(tensor_manip(&format!("{prefix}.shuffle"), b * mid * h * w));
    let (l2, (h2, w2)) =
        conv2d(&format!("{prefix}.dwconv3x3"), b, mid, h, w, mid, 3, 3, stride, mid);
    layers.push(l2);
    // on stride-2 units the output concatenates with an avg-pooled shortcut
    let co_conv = if stride == 2 { co - ci } else { co };
    let (l3, _) = conv2d(&format!("{prefix}.gconv2_1x1"), b, mid, h2, w2, co_conv, 1, 1, 1, g);
    layers.push(l3);
    if stride == 2 {
        layers.push(pool(&format!("{prefix}.shortcut_pool"), b * ci * h * w, b * ci * h2 * w2));
        layers.push(tensor_manip(&format!("{prefix}.concat"), b * co * h2 * w2));
    } else {
        layers.push(elementwise(&format!("{prefix}.add"), b * co * h2 * w2));
    }
    layers.push(elementwise(&format!("{prefix}.relu"), b * co * h2 * w2));
    (h2, w2)
}

/// Faster-RCNN-Shuffle (Rosetta text detection): ShuffleNet-1x (g=4)
/// trunk on a 3x800x600 input + RPN + a proposal-batched head
/// ([25-100 proposals] x [544 or 1088 ch] x [7x7 or 14x14], §2.1.2).
pub fn faster_rcnn_shuffle(proposals: u64) -> ModelDesc {
    let b = 1u64;
    let g = 4u64;
    // ShuffleNet g=4 stage widths
    let (s2, s3, s4) = (272u64, 544, 1088);
    let mut layers = Vec::new();
    let (stem, (mut h, mut w)) = conv2d("stem.conv3x3", b, 3, 800, 600, 24, 3, 3, 2, 1);
    layers.push(stem);
    layers.push(pool("stem.maxpool", b * 24 * h * w, b * 24 * (h / 2) * (w / 2)));
    h /= 2;
    w /= 2;
    let mut ci = 24u64;
    for (si, (width, n_units)) in [(s2, 4u64), (s3, 8), (s4, 4)].iter().enumerate() {
        for u in 0..*n_units {
            let stride = if u == 0 { 2 } else { 1 };
            let (h2, w2) = shuffle_unit(
                &mut layers,
                &format!("stage{}.unit{}", si + 2, u),
                b,
                ci,
                h,
                w,
                *width,
                stride,
                g,
            );
            h = h2;
            w = w2;
            ci = *width;
        }
    }
    // RPN over the s4 feature map
    let (rpn, _) = conv2d("rpn.conv3x3", b, ci, h, w, 256, 3, 3, 1, 1);
    layers.push(rpn);
    let (rpn_cls, _) = conv2d("rpn.cls_1x1", b, 256, h, w, 15, 1, 1, 1, 1);
    layers.push(rpn_cls);
    let (rpn_box, _) = conv2d("rpn.box_1x1", b, 256, h, w, 60, 1, 1, 1, 1);
    layers.push(rpn_box);
    // RoI-align crops proposals from the stage-3 (544-channel) map:
    // activations [proposals x 544 x 14 x 14] (paper: 25-100 proposals x
    // [544 or 1088 ch] x [7,14]^2)
    layers.push(tensor_manip("roi.align", proposals * s3 * 14 * 14));

    // detection head batched over proposals: final shuffle-style stage
    // (544 -> 1088, 14x14 -> 7x7), then cls/box FCs
    let pb = proposals;
    let (hd1, _) = conv2d("head.gconv1_1x1", pb, s3, 14, 14, s3 / 4, 1, 1, 1, g);
    layers.push(hd1);
    let (hd2, _) = conv2d("head.dwconv3x3", pb, s3 / 4, 14, 14, s3 / 4, 3, 3, 2, s3 / 4);
    layers.push(hd2);
    let (hd3, _) = conv2d("head.gconv2_1x1", pb, s3 / 4, 7, 7, s4, 1, 1, 1, g);
    layers.push(hd3);
    layers.push(pool("head.avgpool", pb * s4 * 7 * 7, pb * s4));
    layers.push(fc("head.cls_fc", pb, 2, s4));
    layers.push(fc("head.box_fc", pb, 8, s4));
    layers.push(softmax("head.softmax", pb * 2));

    ModelDesc {
        name: "faster_rcnn_shuffle".to_string(),
        category: Category::ComputerVision,
        batch: 1,
        layers,
        latency: LatencyClass::Relaxed,
    }
}

/// ResNeXt3D-101: clip input (F frames at 112x112 spatial, trading
/// spatial resolution for clip length per the paper), with every
/// bottleneck factorized into 1x1x1 convs + a 3x3x3 *depth-wise*
/// spatiotemporal conv. 97%+ of FLOPs land in the 1x1x1 convolutions.
pub fn resnext3d_101(frames: u64) -> ModelDesc {
    let b = 1u64;
    // the paper trades spatial resolution for clip length: 112x112 crops
    // with longer clips beat 224x224 with fewer frames
    let (mut f, mut h, mut w) = (frames, 112u64, 112u64);
    let mut layers = Vec::new();
    let (stem, (f2, h2, w2)) =
        conv3d("stem.conv1x7x7", b, 3, f, h, w, 64, 1, 7, 7, 1, 2, 1);
    layers.push(stem);
    f = f2;
    h = h2 / 2; // stem pool
    w = w2 / 2;
    layers.push(pool("stem.pool", b * 64 * f2 * h2 * w2, b * 64 * f * h * w));

    let blocks = [3u64, 4, 23, 3];
    let mut ci = 64u64;
    for (s, &n_blocks) in blocks.iter().enumerate() {
        let inner = 64u64 << s; // channel-separated widths (21M params)
        let co = 256u64 << s;
        for blk in 0..n_blocks {
            let stride = if s > 0 && blk == 0 { 2 } else { 1 };
            let stride_t = if s > 0 && blk == 0 && f > 1 { 2 } else { 1 };
            let p = format!("stage{}.block{}", s + 1, blk);
            let (l1, _) = conv3d(&format!("{p}.conv1_1x1x1"), b, ci, f, h, w, inner, 1, 1, 1, 1, 1, 1);
            layers.push(l1);
            let (l2, (f2, h2, w2)) = conv3d(
                &format!("{p}.dwconv3x3x3"),
                b,
                inner,
                f,
                h,
                w,
                inner,
                3,
                3,
                3,
                stride_t,
                stride,
                inner,
            );
            layers.push(l2);
            let (l3, _) =
                conv3d(&format!("{p}.conv3_1x1x1"), b, inner, f2, h2, w2, co, 1, 1, 1, 1, 1, 1);
            layers.push(l3);
            if stride != 1 || ci != co {
                let (proj, _) =
                    conv3d(&format!("{p}.proj"), b, ci, f, h, w, co, 1, 1, 1, stride_t, stride, 1);
                layers.push(proj);
            }
            layers.push(elementwise(&format!("{p}.add_relu"), b * co * f2 * h2 * w2));
            f = f2;
            h = h2;
            w = w2;
            ci = co;
        }
    }
    layers.push(pool("head.avgpool", b * ci * f * h * w, b * ci));
    layers.push(fc("head.fc", b, 400, ci));
    layers.push(softmax("head.softmax", b * 400));
    ModelDesc {
        name: "resnext3d_101".to_string(),
        category: Category::ComputerVision,
        batch: 1,
        layers,
        latency: LatencyClass::Relaxed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::OpClass;

    #[test]
    fn resnet50_param_and_flop_counts_match_paper() {
        let m = resnet50(1);
        // 25.5M params, ~4.1 GMACs = 8.2 GFLOPs at 224x224
        let p = m.params() as f64;
        assert!((24e6..27e6).contains(&p), "params {p}");
        let f = m.flops() as f64;
        assert!((7e9..9e9).contains(&f), "flops {f}");
    }

    #[test]
    fn resnext101_32x4d_matches_paper() {
        let m = resnext101(1, 4);
        // paper: 43M params, 8B multiply-adds (=16B ops)
        let p = m.params() as f64;
        assert!((40e6..48e6).contains(&p), "params {p}");
        let macs = m.flops() as f64 / 2.0;
        assert!((7e9..9.5e9).contains(&macs), "macs {macs}");
    }

    #[test]
    fn resnext101_32x48d_matches_paper() {
        let m = resnext101(1, 48);
        // paper: 829M params, 153B multiply-adds
        let p = m.params() as f64;
        assert!((780e6..880e6).contains(&p), "params {p}");
        let macs = m.flops() as f64 / 2.0;
        assert!((130e9..175e9).contains(&macs), "macs {macs}");
    }

    #[test]
    fn rcnn_shuffle_params_match_paper() {
        let m = faster_rcnn_shuffle(50);
        // paper: 6M params
        let p = m.params() as f64;
        assert!((3e6..8e6).contains(&p), "params {p}");
        // detection input 9.5x larger than classification
        let input = m.layers[0].act_in_elems as f64;
        assert!((input / (3.0 * 224.0 * 224.0) - 9.56).abs() < 0.3);
    }

    #[test]
    fn rcnn_head_shapes_are_proposal_batched() {
        let m = faster_rcnn_shuffle(100);
        let head = m.layers.iter().find(|l| l.name == "head.gconv1_1x1").unwrap();
        let g = head.gemm.unwrap();
        assert_eq!(g.m, 100 * 14 * 14);
        assert_eq!(g.groups, 4);
    }

    #[test]
    fn resnext3d_params_match_paper() {
        let m = resnext3d_101(32);
        // paper: 21M params
        let p = m.params() as f64;
        assert!((17e6..26e6).contains(&p), "params {p}");
    }

    #[test]
    fn resnext3d_flops_dominated_by_1x1x1() {
        let m = resnext3d_101(32);
        let total = m.flops() as f64;
        let pointwise: u64 = m
            .layers
            .iter()
            .filter(|l| l.name.contains("1x1x1") || l.name.contains("proj"))
            .map(|l| l.flops)
            .sum();
        // paper: 97.1% of FLOPs in 1x1x1 convolutions
        assert!(pointwise as f64 / total > 0.88, "{}", pointwise as f64 / total); // paper: 97.1% within the residual blocks; our share includes the stem
        let dw: u64 = m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::DepthwiseConv)
            .map(|l| l.flops)
            .sum();
        assert!((dw as f64 / total) < 0.05);
    }

    #[test]
    fn max_live_activations_scale_with_input() {
        // Table 1: ResNet-50 ~2M, ResNeXt3D ~58M live activations
        let r50 = resnet50(1).max_live_activations() as f64;
        assert!((1e6..4e6).contains(&r50), "{r50}");
        // our live-set proxy is per-layer (in + out), a lower bound on
        // the paper's whole-graph 58M live set
        let v = resnext3d_101(32).max_live_activations() as f64;
        assert!((8e6..80e6).contains(&v), "{v}");
        let det = faster_rcnn_shuffle(50).max_live_activations() as f64;
        assert!((8e6..16e6).contains(&det), "{det}"); // paper: 13.2M
    }
}
