//! Model zoo: layer-graph descriptors of every Table-1 model.
//!
//! Table 1, Fig 3 (roofline), Fig 4 (fleet op shares) and Fig 5 (matrix
//! shapes) depend only on per-layer *shapes* — all public in the papers
//! the models come from — so the zoo describes each model as an ordered
//! list of [`Layer`]s carrying op class, FLOPs, weight/activation
//! element counts and (when GEMM-lowerable) the (M, N, K, G) shape.
//!
//! Builders:
//! - [`recsys`]       — Fig-2 recommendation model (embeddings + MLPs)
//! - [`resnet50`]     — classification baseline (§2.1.2)
//! - [`resnext101`]   — ResNeXt-101-32x4d / 32x48d group-conv models
//! - [`faster_rcnn_shuffle`] — Rosetta text detection (ShuffleNet trunk)
//! - [`resnext3d_101`] — video model, depth-wise spatiotemporal factorization
//! - [`seq2seq_gru`]  — NMT encoder/decoder (§2.1.3)
//!
//! [`serving`] holds the [`crate::coordinator::ModelService`] impls that
//! make the servable members of each family runnable on the frontend.

pub mod cv;
pub mod nmt;
pub mod rec;
pub mod serving;
pub mod zoo;

pub use cv::{faster_rcnn_shuffle, resnet50, resnext101, resnext3d_101};
pub use nmt::{seq2seq_default, seq2seq_gru, seq2seq_lstm, LengthDistribution, SeqDecodeSpec};
pub use rec::{recsys, RecsysScale};
pub use serving::{CvService, NmtService, RecSysService};
pub use zoo::{representative_zoo, zoo_entry, ZooEntry};

/// Operator class, following the Caffe2 buckets of Fig 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Fully connected (Caffe2 `FC`): the paper's top CPU consumer.
    Fc,
    /// Dense convolution (lowered to GEMM via im2col shapes).
    Conv,
    /// Group convolution (G independent narrow GEMMs).
    GroupConv,
    /// Depth-wise convolution (bandwidth bound, §2.1.2).
    DepthwiseConv,
    /// Embedding lookup (`SparseLengthsSum`).
    Embedding,
    /// Recurrent cell matmuls (GRU/LSTM gates).
    Recurrent,
    /// Elementwise / activation ops.
    Elementwise,
    /// Concat/split/slice/transpose ("Tensor Manipulation" in Fig 4).
    TensorManip,
    /// Pooling.
    Pool,
    /// Softmax / normalization.
    Softmax,
}

impl OpClass {
    /// Fig-4 bucket name.
    pub fn bucket(self) -> &'static str {
        match self {
            OpClass::Fc => "FC",
            OpClass::Conv | OpClass::GroupConv | OpClass::DepthwiseConv => "Conv",
            OpClass::Embedding => "Embedding",
            OpClass::Recurrent => "Recurrent",
            OpClass::Elementwise => "Elementwise",
            OpClass::TensorManip => "TensorManip",
            OpClass::Pool => "Pool",
            OpClass::Softmax => "Softmax",
        }
    }
}

/// GEMM lowering of a layer: `[M x K] * [K x N]` per group (Fig 5 axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub groups: u64,
}

/// One layer of a model descriptor.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub class: OpClass,
    /// multiply-add counted as 2 ops
    pub flops: u64,
    /// total parameter storage (capacity)
    pub weight_elems: u64,
    /// weight elements actually read per evaluation (= weight_elems for
    /// dense layers; only the touched rows for embedding lookups)
    pub weight_traffic_elems: u64,
    pub act_in_elems: u64,
    pub act_out_elems: u64,
    pub gemm: Option<GemmShape>,
}

impl Layer {
    /// Ops per weight element read (≈ 2M for a GEMM) — Table 1 col 6.
    pub fn ops_per_weight(&self) -> f64 {
        if self.weight_traffic_elems == 0 {
            f64::INFINITY
        } else {
            self.flops as f64 / self.weight_traffic_elems as f64
        }
    }

    /// Ops per element of total traffic (weights + activations) — col 7.
    pub fn ops_per_elem(&self) -> f64 {
        let traffic = self.weight_traffic_elems + self.act_in_elems + self.act_out_elems;
        if traffic == 0 {
            f64::INFINITY
        } else {
            self.flops as f64 / traffic as f64
        }
    }
}

/// Inference latency constraint class (Table 1 last column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatencyClass {
    /// "10s of ms" — ranking/recommendation and interactive NMT.
    TensMs,
    /// No strict constraint (offline CV understanding).
    Relaxed,
}

/// Workload category (Table 1 col 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Recommendation,
    ComputerVision,
    Language,
}

/// A model descriptor: ordered layers plus serving metadata.
#[derive(Debug, Clone)]
pub struct ModelDesc {
    pub name: String,
    pub category: Category,
    pub batch: u64,
    pub layers: Vec<Layer>,
    pub latency: LatencyClass,
}

impl ModelDesc {
    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems).sum()
    }

    /// Unique parameter count: weights shared across unrolled decode
    /// steps (`...stepNN...` layers) are counted once.
    pub fn unique_params(&self) -> u64 {
        let mut seen = std::collections::HashSet::new();
        let mut total = 0u64;
        for l in &self.layers {
            let canon: String = l
                .name
                .split('.')
                .filter(|p| !p.starts_with("step"))
                .collect::<Vec<_>>()
                .join(".");
            if seen.insert(canon) {
                total += l.weight_elems;
            }
        }
        total
    }

    pub fn flops(&self) -> u64 {
        self.layers.iter().map(|l| l.flops).sum()
    }

    /// Max live activations: the peak of (input + output) elements over
    /// layers — the Table-1 "Max. Live Activations" proxy.
    pub fn max_live_activations(&self) -> u64 {
        self.layers.iter().map(|l| l.act_in_elems + l.act_out_elems).max().unwrap_or(0)
    }

    /// Model-level arithmetic intensity counting only weight traffic.
    pub fn intensity_weights(&self) -> f64 {
        let w: u64 = self.layers.iter().map(|l| l.weight_traffic_elems).sum();
        if w == 0 {
            f64::INFINITY
        } else {
            self.flops() as f64 / w as f64
        }
    }

    /// Min per-layer ops/weight over layers that have weights.
    pub fn min_ops_per_weight(&self) -> f64 {
        self.layers
            .iter()
            .filter(|l| l.weight_traffic_elems > 0)
            .map(|l| l.ops_per_weight())
            .fold(f64::INFINITY, f64::min)
    }

    /// Model-level intensity counting weights + activations.
    pub fn intensity_full(&self) -> f64 {
        let t: u64 = self
            .layers
            .iter()
            .map(|l| l.weight_traffic_elems + l.act_in_elems + l.act_out_elems)
            .sum();
        if t == 0 {
            f64::INFINITY
        } else {
            self.flops() as f64 / t as f64
        }
    }

    /// Min per-layer full intensity.
    pub fn min_intensity_full(&self) -> f64 {
        self.layers
            .iter()
            .filter(|l| l.weight_traffic_elems > 0)
            .map(|l| l.ops_per_elem())
            .fold(f64::INFINITY, f64::min)
    }

    /// All GEMM shapes in the model (Fig 5 scatter points).
    pub fn gemm_shapes(&self) -> Vec<(OpClass, GemmShape)> {
        self.layers.iter().filter_map(|l| l.gemm.map(|g| (l.class, g))).collect()
    }
}

// ---------------------------------------------------------------------------
// Layer constructors shared by the builders
// ---------------------------------------------------------------------------

/// 2D convolution descriptor (NCHW, SAME-style integer output size).
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    name: &str,
    b: u64,
    ci: u64,
    h: u64,
    w: u64,
    co: u64,
    kh: u64,
    kw: u64,
    stride: u64,
    groups: u64,
) -> (Layer, (u64, u64)) {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let m = b * ho * wo;
    let n_per_g = co / groups;
    let k_per_g = (ci / groups) * kh * kw;
    let flops = 2 * m * n_per_g * k_per_g * groups;
    let class = if groups == 1 {
        OpClass::Conv
    } else if groups == ci && ci == co {
        OpClass::DepthwiseConv
    } else {
        OpClass::GroupConv
    };
    let layer = Layer {
        name: name.to_string(),
        class,
        flops,
        weight_elems: co * (ci / groups) * kh * kw,
        weight_traffic_elems: co * (ci / groups) * kh * kw,
        act_in_elems: b * ci * h * w,
        act_out_elems: b * co * ho * wo,
        gemm: Some(GemmShape { m, n: n_per_g, k: k_per_g, groups }),
    };
    (layer, (ho, wo))
}

/// 3D convolution (video): F frames in/out follow the stride on t.
#[allow(clippy::too_many_arguments)]
pub fn conv3d(
    name: &str,
    b: u64,
    ci: u64,
    f: u64,
    h: u64,
    w: u64,
    co: u64,
    kt: u64,
    kh: u64,
    kw: u64,
    stride_t: u64,
    stride_s: u64,
    groups: u64,
) -> (Layer, (u64, u64, u64)) {
    let fo = f.div_ceil(stride_t);
    let ho = h.div_ceil(stride_s);
    let wo = w.div_ceil(stride_s);
    let m = b * fo * ho * wo;
    let n_per_g = co / groups;
    let k_per_g = (ci / groups) * kt * kh * kw;
    let flops = 2 * m * n_per_g * k_per_g * groups;
    let class = if groups == 1 {
        OpClass::Conv
    } else if groups == ci && ci == co {
        OpClass::DepthwiseConv
    } else {
        OpClass::GroupConv
    };
    let layer = Layer {
        name: name.to_string(),
        class,
        flops,
        weight_elems: co * (ci / groups) * kt * kh * kw,
        weight_traffic_elems: co * (ci / groups) * kt * kh * kw,
        act_in_elems: b * ci * f * h * w,
        act_out_elems: b * co * fo * ho * wo,
        gemm: Some(GemmShape { m, n: n_per_g, k: k_per_g, groups }),
    };
    (layer, (fo, ho, wo))
}

/// Fully connected: `out = X[MxK] * W^T[KxN]` (Caffe2 convention).
pub fn fc(name: &str, m: u64, n: u64, k: u64) -> Layer {
    Layer {
        name: name.to_string(),
        class: OpClass::Fc,
        flops: 2 * m * n * k,
        weight_elems: n * k + n,
        weight_traffic_elems: n * k + n,
        act_in_elems: m * k,
        act_out_elems: m * n,
        gemm: Some(GemmShape { m, n, k, groups: 1 }),
    }
}

/// SparseLengthsSum over a table of `rows x dim`, `pool` lookups per bag.
pub fn embedding(name: &str, batch: u64, rows: u64, dim: u64, pool: u64) -> Layer {
    Layer {
        name: name.to_string(),
        class: OpClass::Embedding,
        // pooling adds dim flops per gathered row
        flops: batch * pool * dim,
        weight_elems: rows * dim,
        // only the gathered rows are read: the paper's intensity ~1-2
        weight_traffic_elems: batch * pool * dim,
        act_in_elems: batch * pool, // the indices
        act_out_elems: batch * dim,
        gemm: None,
    }
}

/// Elementwise op over `elems` elements (ReLU, add, sigmoid...).
pub fn elementwise(name: &str, elems: u64) -> Layer {
    Layer {
        name: name.to_string(),
        class: OpClass::Elementwise,
        flops: elems,
        weight_elems: 0,
        weight_traffic_elems: 0,
        act_in_elems: elems,
        act_out_elems: elems,
        gemm: None,
    }
}

/// Tensor manipulation (concat/split/transpose): pure data movement.
pub fn tensor_manip(name: &str, elems: u64) -> Layer {
    Layer {
        name: name.to_string(),
        class: OpClass::TensorManip,
        flops: 0,
        weight_elems: 0,
        weight_traffic_elems: 0,
        act_in_elems: elems,
        act_out_elems: elems,
        gemm: None,
    }
}

/// Pooling over spatial dims.
pub fn pool(name: &str, in_elems: u64, out_elems: u64) -> Layer {
    Layer {
        name: name.to_string(),
        class: OpClass::Pool,
        flops: in_elems,
        weight_elems: 0,
        weight_traffic_elems: 0,
        act_in_elems: in_elems,
        act_out_elems: out_elems,
        gemm: None,
    }
}

/// Softmax over `elems`.
pub fn softmax(name: &str, elems: u64) -> Layer {
    Layer {
        name: name.to_string(),
        class: OpClass::Softmax,
        flops: 5 * elems, // exp + sum + div
        weight_elems: 0,
        weight_traffic_elems: 0,
        act_in_elems: elems,
        act_out_elems: elems,
        gemm: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv2d_shapes_and_flops() {
        // 3x224x224 -> 64 channels, 7x7 stride 2: the ResNet stem
        let (l, (ho, wo)) = conv2d("stem", 1, 3, 224, 224, 64, 7, 7, 2, 1);
        assert_eq!((ho, wo), (112, 112));
        assert_eq!(l.weight_elems, 64 * 3 * 49);
        assert_eq!(l.flops, 2 * 112 * 112 * 64 * 3 * 49);
        assert_eq!(l.class, OpClass::Conv);
        let g = l.gemm.unwrap();
        assert_eq!((g.m, g.n, g.k, g.groups), (112 * 112, 64, 147, 1));
    }

    #[test]
    fn depthwise_classification() {
        let (l, _) = conv2d("dw", 1, 64, 56, 56, 64, 3, 3, 1, 64);
        assert_eq!(l.class, OpClass::DepthwiseConv);
        assert_eq!(l.weight_elems, 64 * 9);
        let g = l.gemm.unwrap();
        assert_eq!(g.n, 1);
        assert_eq!(g.k, 9);
    }

    #[test]
    fn group_conv_classification() {
        let (l, _) = conv2d("g", 1, 256, 56, 56, 256, 1, 1, 1, 32);
        assert_eq!(l.class, OpClass::GroupConv);
        let g = l.gemm.unwrap();
        assert_eq!(g.n, 8); // 256/32 output channels per group
    }

    #[test]
    fn fc_intensity_is_2m() {
        let l = fc("fc", 10, 64, 512);
        // ops per weight ~ 2*M (bias makes it slightly lower)
        assert!((l.ops_per_weight() - 2.0 * 10.0).abs() < 0.5);
    }

    #[test]
    fn embedding_low_intensity() {
        let l = embedding("emb", 16, 10_000_000, 64, 32);
        // Table 1: embeddings are intensity 1-2 over *touched* rows
        assert!(l.ops_per_weight() >= 0.9 && l.ops_per_weight() <= 2.0);
        assert_eq!(l.act_out_elems, 16 * 64);
    }
}
