//! Neural machine translation descriptor (§2.1.3): seq2seq with GRU
//! encoder/decoder. Table-1 row: 100M-1B params, batch 1-8 tokens,
//! arithmetic intensity 2-20, 10s-of-ms latency budget.
//!
//! Inference decodes autoregressively with beam search, so the decoder
//! GRU runs `out_len * beam`-row GEMMs — the canonical small-batch,
//! bandwidth-bound workload of §2.2.
//!
//! Besides the roofline descriptors, this module owns the *decode
//! semantics* the sequence-serving plane executes: [`SeqDecodeSpec`]
//! (greedy argmax over the logits head, a deterministic token
//! embedding, EOS detection) and [`LengthDistribution`] (the
//! geometric/uniform output-length mixes `dcinfer loadgen --seq`
//! drives). Both the server's continuous-batching loop
//! ([`crate::coordinator::seqserve`]) and the single-sequence
//! reference decode evaluate exactly these functions, which is what
//! makes the bit-identical contract testable.

use anyhow::{bail, ensure, Context, Result};

use crate::util::rng::Pcg32;

use super::{elementwise, embedding, fc, softmax, Category, LatencyClass, Layer, ModelDesc};

/// The greedy decode-loop semantics for a `gru_step` artifact family:
/// every step runs `(x, h) -> (logits, h_new)`, the next token is the
/// argmax of the logits row, and the next `x` is a deterministic
/// embedding of that token. Shared verbatim by the server-owned decode
/// loop and the single-sequence reference, so a sequence decoded inside
/// any batch composition produces the same token stream as one decoded
/// alone (the fp32 native GEMM computes each output row as an
/// independent k-ascending chain — batch neighbors never perturb it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqDecodeSpec {
    /// decoder state width (== the embedded-token width)
    pub hidden: usize,
    /// logits-head width
    pub vocab: usize,
    /// token id that terminates a sequence early
    pub eos: u32,
}

impl SeqDecodeSpec {
    /// Deterministic per-token embedding: the same token id always maps
    /// to the same N(0,1) vector (seeded by the id), on every replica —
    /// a fixture-sized stand-in for a real embedding table that keeps
    /// the decode loop closed without shipping vocab × hidden weights.
    pub fn token_embedding(&self, token: u32) -> Vec<f32> {
        let mut rng = Pcg32::new(0x5eed_70c0 ^ u64::from(token), u64::from(token).wrapping_add(1));
        let mut x = vec![0f32; self.hidden];
        rng.fill_normal(&mut x, 0.0, 1.0);
        x
    }

    /// Greedy head: index of the first maximal logit (ties break to the
    /// lowest index, NaNs never win).
    pub fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in logits.iter().enumerate() {
            if v > best_v {
                best = i;
                best_v = v;
            }
        }
        best as u32
    }
}

/// Output-length distribution for sequence load generation — the
/// mixed-length regime where continuous batching pays off (short
/// sequences exit early and free their slot instead of padding to the
/// longest neighbor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDistribution {
    /// Geometric with the given mean: the memoryless "every step might
    /// be the last" model of EOS emission.
    Geometric { mean: f64 },
    /// Uniform over `[lo, hi]` inclusive.
    Uniform { lo: u32, hi: u32 },
}

impl LengthDistribution {
    /// Parse the CLI forms `geom:MEAN` and `uniform:LO,HI`.
    pub fn parse(s: &str) -> Result<LengthDistribution> {
        let (kind, args) = s.split_once(':').context("expected geom:MEAN or uniform:LO,HI")?;
        match kind {
            "geom" | "geometric" => {
                let mean: f64 = args.parse().with_context(|| format!("bad mean {args:?}"))?;
                ensure!(mean >= 1.0 && mean.is_finite(), "geometric mean must be >= 1");
                Ok(LengthDistribution::Geometric { mean })
            }
            "uniform" => {
                let (lo, hi) = args.split_once(',').context("uniform wants LO,HI")?;
                let lo: u32 = lo.parse().with_context(|| format!("bad lo {lo:?}"))?;
                let hi: u32 = hi.parse().with_context(|| format!("bad hi {hi:?}"))?;
                ensure!(lo >= 1 && lo <= hi, "uniform wants 1 <= lo <= hi");
                Ok(LengthDistribution::Uniform { lo, hi })
            }
            other => bail!("unknown length distribution {other:?} (geom:MEAN | uniform:LO,HI)"),
        }
    }

    /// Draw one output length, clamped to `[1, cap]`.
    pub fn sample(&self, rng: &mut Pcg32, cap: u32) -> u32 {
        let len = match *self {
            LengthDistribution::Geometric { mean } => {
                // inverse-CDF: L = 1 + floor(ln U / ln(1-p)), p = 1/mean
                let p = 1.0 / mean;
                if p >= 1.0 {
                    1
                } else {
                    // 1 - uniform() is in (0, 1]; ln(1) = 0 gives L = 1
                    let u = 1.0 - rng.uniform();
                    1 + (u.ln() / (1.0 - p).ln()) as u32
                }
            }
            LengthDistribution::Uniform { lo, hi } => lo + rng.below(hi - lo + 1),
        };
        len.clamp(1, cap.max(1))
    }

    /// Expected length (before the cap).
    pub fn mean(&self) -> f64 {
        match *self {
            LengthDistribution::Geometric { mean } => mean,
            LengthDistribution::Uniform { lo, hi } => (lo as f64 + hi as f64) / 2.0,
        }
    }
}

/// One GRU cell step as three gate GEMMs (W and U fused per gate pair).
fn gru_cell(layers: &mut Vec<Layer>, prefix: &str, rows: u64, hidden: u64) {
    // 3 gates x (W x + U h): lower as [rows, 2H] x [2H, 3H]
    let mut l = fc(&format!("{prefix}.gates"), rows, 3 * hidden, 2 * hidden);
    l.class = super::OpClass::Recurrent;
    layers.push(l);
    layers.push(elementwise(&format!("{prefix}.gate_act"), rows * 3 * hidden));
    layers.push(elementwise(&format!("{prefix}.blend"), rows * hidden));
}

/// One LSTM cell step: four gates (i, f, g, o) + cell blend — the
/// paper's other recurrent option ("GRU [12] or LSTM [29] cells").
/// 33% more gate parameters than GRU at the same hidden size.
fn lstm_cell(layers: &mut Vec<Layer>, prefix: &str, rows: u64, hidden: u64) {
    let mut l = fc(&format!("{prefix}.gates"), rows, 4 * hidden, 2 * hidden);
    l.class = super::OpClass::Recurrent;
    layers.push(l);
    layers.push(elementwise(&format!("{prefix}.gate_act"), rows * 4 * hidden));
    layers.push(elementwise(&format!("{prefix}.cell_blend"), rows * 2 * hidden));
}

/// seq2seq GRU NMT model.
///
/// * `batch`    — sentences decoded together (1-8 in Table 1)
/// * `in_len`   — source sentence length
/// * `out_len`  — decoded length
/// * `beam`     — beam width (decoder effective rows = batch*beam)
pub fn seq2seq_gru(
    batch: u64,
    in_len: u64,
    out_len: u64,
    beam: u64,
    hidden: u64,
    layers_per_dir: u64,
    vocab: u64,
) -> ModelDesc {
    let mut layers = Vec::new();
    // source token embedding (lookup table, pool=1)
    layers.push(embedding("enc.embed", batch * in_len, vocab, hidden, 1));
    // encoder: bidirectional-ish stack, processes the whole source; the
    // GEMM batches over all source positions.
    for l in 0..layers_per_dir {
        gru_cell(&mut layers, &format!("enc.layer{l}"), batch * in_len, hidden);
    }
    // decoder: one step at a time (autoregressive), beam-expanded rows
    let dec_rows = batch * beam;
    for step in 0..out_len {
        layers.push(embedding(&format!("dec.step{step}.embed"), dec_rows, vocab, hidden, 1));
        for l in 0..layers_per_dir {
            gru_cell(&mut layers, &format!("dec.step{step}.layer{l}"), dec_rows, hidden);
        }
        // attention over source states
        let mut att = fc(&format!("dec.step{step}.attn_score"), dec_rows, in_len, hidden);
        att.class = super::OpClass::Recurrent;
        layers.push(att);
        layers.push(softmax(&format!("dec.step{step}.attn_softmax"), dec_rows * in_len));
        layers.push(elementwise(&format!("dec.step{step}.attn_mix"), dec_rows * hidden * 2));
        // output projection to vocab
        layers.push(fc(&format!("dec.step{step}.proj_vocab"), dec_rows, vocab, hidden));
        layers.push(softmax(&format!("dec.step{step}.softmax"), dec_rows * vocab));
    }
    ModelDesc {
        name: format!("seq2seq_gru_b{batch}"),
        category: Category::Language,
        batch,
        layers,
        latency: LatencyClass::TensMs,
    }
}

/// The Table-1 configuration: hidden 1024, 4 layers, 32k vocab.
pub fn seq2seq_default(batch: u64) -> ModelDesc {
    seq2seq_gru(batch, 20, 20, 4, 1024, 4, 32_768)
}

/// LSTM variant of the Table-1 seq2seq model (same topology, 4-gate
/// cells). Used by the characterization tests to confirm the Table-1
/// bands are cell-agnostic.
pub fn seq2seq_lstm(batch: u64, in_len: u64, out_len: u64, beam: u64, hidden: u64,
                    layers_per_dir: u64, vocab: u64) -> ModelDesc {
    let mut layers = Vec::new();
    layers.push(embedding("enc.embed", batch * in_len, vocab, hidden, 1));
    for l in 0..layers_per_dir {
        lstm_cell(&mut layers, &format!("enc.layer{l}"), batch * in_len, hidden);
    }
    let dec_rows = batch * beam;
    for step in 0..out_len {
        layers.push(embedding(&format!("dec.step{step}.embed"), dec_rows, vocab, hidden, 1));
        for l in 0..layers_per_dir {
            lstm_cell(&mut layers, &format!("dec.step{step}.layer{l}"), dec_rows, hidden);
        }
        layers.push(fc(&format!("dec.step{step}.proj_vocab"), dec_rows, vocab, hidden));
        layers.push(softmax(&format!("dec.step{step}.softmax"), dec_rows * vocab));
    }
    ModelDesc {
        name: format!("seq2seq_lstm_b{batch}"),
        category: Category::Language,
        batch,
        layers,
        latency: LatencyClass::TensMs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::OpClass;

    /// Unique parameter count (weights shared across decode steps are
    /// counted once here).
    fn unique_params(m: &ModelDesc) -> u64 {
        // encoder + one decoder step's recurrent/fc weights + embeddings
        let mut seen = std::collections::HashSet::new();
        let mut total = 0u64;
        for l in &m.layers {
            // strip the stepNN. component to dedupe shared weights
            let canon = l
                .name
                .split('.')
                .filter(|p| !p.starts_with("step"))
                .collect::<Vec<_>>()
                .join(".");
            if seen.insert(canon) {
                total += l.weight_elems;
            }
        }
        total
    }

    #[test]
    fn params_in_table1_range() {
        let m = seq2seq_default(1);
        let p = unique_params(&m);
        // Table 1: 100M-1B params
        assert!((90_000_000..1_000_000_000).contains(&p), "{p}");
    }

    #[test]
    fn decoder_gemms_are_tall_skinny() {
        // batch 1, beam 4: decoder GEMM rows = 4 — the Fig-5 triangle zone
        let m = seq2seq_default(1);
        let dec_gates: Vec<_> = m
            .layers
            .iter()
            .filter(|l| l.name.contains("dec.") && l.name.contains("gates"))
            .collect();
        assert!(!dec_gates.is_empty());
        for l in dec_gates {
            assert_eq!(l.gemm.unwrap().m, 4);
        }
    }

    #[test]
    fn recurrent_intensity_in_table1_band() {
        // Table 1: seq2seq intensity 2-20. The band is set by the
        // *decoder* (1-8 effective rows); the encoder batches over all
        // source positions and is naturally denser.
        let m = seq2seq_default(2);
        let dec: Vec<_> = m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Recurrent && l.name.starts_with("dec."))
            .collect();
        assert!(!dec.is_empty());
        for l in dec {
            let i = l.ops_per_weight();
            assert!((2.0..=20.0).contains(&i), "{} intensity {i}", l.name);
        }
    }

    #[test]
    fn lstm_has_more_gate_params_than_gru() {
        let gru = seq2seq_gru(1, 20, 20, 4, 1024, 4, 32_768);
        let lstm = seq2seq_lstm(1, 20, 20, 4, 1024, 4, 32_768);
        let gates = |m: &ModelDesc| -> u64 {
            m.layers
                .iter()
                .filter(|l| l.class == OpClass::Recurrent && l.name.starts_with("enc."))
                .map(|l| l.weight_elems)
                .sum()
        };
        // 4 gates vs 3: exactly 4/3 the recurrent parameters
        let (g, l) = (gates(&gru) as f64, gates(&lstm) as f64);
        assert!((l / g - 4.0 / 3.0).abs() < 0.01, "{l} / {g}");
    }

    #[test]
    fn lstm_decoder_stays_in_table1_intensity_band() {
        let m = seq2seq_lstm(2, 20, 20, 4, 1024, 4, 32_768);
        for l in m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Recurrent && l.name.starts_with("dec."))
        {
            let i = l.ops_per_weight();
            assert!((2.0..=20.0).contains(&i), "{} intensity {i}", l.name);
        }
    }

    #[test]
    fn token_embedding_is_deterministic_and_token_keyed() {
        let spec = SeqDecodeSpec { hidden: 8, vocab: 16, eos: 0 };
        let a = spec.token_embedding(3);
        assert_eq!(a.len(), 8);
        assert_eq!(a, spec.token_embedding(3), "same token, same vector, always");
        assert_ne!(a, spec.token_embedding(4), "distinct tokens embed differently");
    }

    #[test]
    fn argmax_breaks_ties_low_and_ignores_nan() {
        assert_eq!(SeqDecodeSpec::argmax(&[0.0, 2.0, 2.0, 1.0]), 1);
        assert_eq!(SeqDecodeSpec::argmax(&[f32::NAN, -1.0, 3.0]), 2);
        assert_eq!(SeqDecodeSpec::argmax(&[-5.0]), 0);
    }

    #[test]
    fn length_distributions_parse_sample_and_reject_garbage() {
        use crate::util::rng::Pcg32;
        let g = LengthDistribution::parse("geom:12").unwrap();
        assert_eq!(g, LengthDistribution::Geometric { mean: 12.0 });
        let u = LengthDistribution::parse("uniform:4,24").unwrap();
        assert_eq!(u, LengthDistribution::Uniform { lo: 4, hi: 24 });
        for bad in ["", "geom", "geom:0.5", "uniform:9,3", "uniform:0,3", "pareto:2"] {
            assert!(LengthDistribution::parse(bad).is_err(), "{bad:?} parsed");
        }
        let mut rng = Pcg32::seeded(9);
        let mut sum = 0u64;
        for _ in 0..4000 {
            let l = g.sample(&mut rng, 1000);
            assert!((1..=1000).contains(&l));
            sum += u64::from(l);
        }
        let mean = sum as f64 / 4000.0;
        assert!((mean - 12.0).abs() < 1.5, "geometric mean drifted: {mean}");
        for _ in 0..200 {
            let l = u.sample(&mut rng, 16);
            assert!((4..=16).contains(&l), "cap applies: {l}");
        }
    }

    #[test]
    fn decode_steps_scale_layers() {
        let short = seq2seq_gru(1, 10, 5, 4, 256, 2, 1000);
        let long = seq2seq_gru(1, 10, 20, 4, 256, 2, 1000);
        assert!(long.layers.len() > short.layers.len());
        assert!(long.flops() > 3 * short.flops() / 2);
    }
}
