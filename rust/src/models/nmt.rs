//! Neural machine translation descriptor (§2.1.3): seq2seq with GRU
//! encoder/decoder. Table-1 row: 100M-1B params, batch 1-8 tokens,
//! arithmetic intensity 2-20, 10s-of-ms latency budget.
//!
//! Inference decodes autoregressively with beam search, so the decoder
//! GRU runs `out_len * beam`-row GEMMs — the canonical small-batch,
//! bandwidth-bound workload of §2.2.

use super::{elementwise, embedding, fc, softmax, Category, LatencyClass, Layer, ModelDesc};

/// One GRU cell step as three gate GEMMs (W and U fused per gate pair).
fn gru_cell(layers: &mut Vec<Layer>, prefix: &str, rows: u64, hidden: u64) {
    // 3 gates x (W x + U h): lower as [rows, 2H] x [2H, 3H]
    let mut l = fc(&format!("{prefix}.gates"), rows, 3 * hidden, 2 * hidden);
    l.class = super::OpClass::Recurrent;
    layers.push(l);
    layers.push(elementwise(&format!("{prefix}.gate_act"), rows * 3 * hidden));
    layers.push(elementwise(&format!("{prefix}.blend"), rows * hidden));
}

/// One LSTM cell step: four gates (i, f, g, o) + cell blend — the
/// paper's other recurrent option ("GRU [12] or LSTM [29] cells").
/// 33% more gate parameters than GRU at the same hidden size.
fn lstm_cell(layers: &mut Vec<Layer>, prefix: &str, rows: u64, hidden: u64) {
    let mut l = fc(&format!("{prefix}.gates"), rows, 4 * hidden, 2 * hidden);
    l.class = super::OpClass::Recurrent;
    layers.push(l);
    layers.push(elementwise(&format!("{prefix}.gate_act"), rows * 4 * hidden));
    layers.push(elementwise(&format!("{prefix}.cell_blend"), rows * 2 * hidden));
}

/// seq2seq GRU NMT model.
///
/// * `batch`    — sentences decoded together (1-8 in Table 1)
/// * `in_len`   — source sentence length
/// * `out_len`  — decoded length
/// * `beam`     — beam width (decoder effective rows = batch*beam)
pub fn seq2seq_gru(
    batch: u64,
    in_len: u64,
    out_len: u64,
    beam: u64,
    hidden: u64,
    layers_per_dir: u64,
    vocab: u64,
) -> ModelDesc {
    let mut layers = Vec::new();
    // source token embedding (lookup table, pool=1)
    layers.push(embedding("enc.embed", batch * in_len, vocab, hidden, 1));
    // encoder: bidirectional-ish stack, processes the whole source; the
    // GEMM batches over all source positions.
    for l in 0..layers_per_dir {
        gru_cell(&mut layers, &format!("enc.layer{l}"), batch * in_len, hidden);
    }
    // decoder: one step at a time (autoregressive), beam-expanded rows
    let dec_rows = batch * beam;
    for step in 0..out_len {
        layers.push(embedding(&format!("dec.step{step}.embed"), dec_rows, vocab, hidden, 1));
        for l in 0..layers_per_dir {
            gru_cell(&mut layers, &format!("dec.step{step}.layer{l}"), dec_rows, hidden);
        }
        // attention over source states
        let mut att = fc(&format!("dec.step{step}.attn_score"), dec_rows, in_len, hidden);
        att.class = super::OpClass::Recurrent;
        layers.push(att);
        layers.push(softmax(&format!("dec.step{step}.attn_softmax"), dec_rows * in_len));
        layers.push(elementwise(&format!("dec.step{step}.attn_mix"), dec_rows * hidden * 2));
        // output projection to vocab
        layers.push(fc(&format!("dec.step{step}.proj_vocab"), dec_rows, vocab, hidden));
        layers.push(softmax(&format!("dec.step{step}.softmax"), dec_rows * vocab));
    }
    ModelDesc {
        name: format!("seq2seq_gru_b{batch}"),
        category: Category::Language,
        batch,
        layers,
        latency: LatencyClass::TensMs,
    }
}

/// The Table-1 configuration: hidden 1024, 4 layers, 32k vocab.
pub fn seq2seq_default(batch: u64) -> ModelDesc {
    seq2seq_gru(batch, 20, 20, 4, 1024, 4, 32_768)
}

/// LSTM variant of the Table-1 seq2seq model (same topology, 4-gate
/// cells). Used by the characterization tests to confirm the Table-1
/// bands are cell-agnostic.
pub fn seq2seq_lstm(batch: u64, in_len: u64, out_len: u64, beam: u64, hidden: u64,
                    layers_per_dir: u64, vocab: u64) -> ModelDesc {
    let mut layers = Vec::new();
    layers.push(embedding("enc.embed", batch * in_len, vocab, hidden, 1));
    for l in 0..layers_per_dir {
        lstm_cell(&mut layers, &format!("enc.layer{l}"), batch * in_len, hidden);
    }
    let dec_rows = batch * beam;
    for step in 0..out_len {
        layers.push(embedding(&format!("dec.step{step}.embed"), dec_rows, vocab, hidden, 1));
        for l in 0..layers_per_dir {
            lstm_cell(&mut layers, &format!("dec.step{step}.layer{l}"), dec_rows, hidden);
        }
        layers.push(fc(&format!("dec.step{step}.proj_vocab"), dec_rows, vocab, hidden));
        layers.push(softmax(&format!("dec.step{step}.softmax"), dec_rows * vocab));
    }
    ModelDesc {
        name: format!("seq2seq_lstm_b{batch}"),
        category: Category::Language,
        batch,
        layers,
        latency: LatencyClass::TensMs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::OpClass;

    /// Unique parameter count (weights shared across decode steps are
    /// counted once here).
    fn unique_params(m: &ModelDesc) -> u64 {
        // encoder + one decoder step's recurrent/fc weights + embeddings
        let mut seen = std::collections::HashSet::new();
        let mut total = 0u64;
        for l in &m.layers {
            // strip the stepNN. component to dedupe shared weights
            let canon = l
                .name
                .split('.')
                .filter(|p| !p.starts_with("step"))
                .collect::<Vec<_>>()
                .join(".");
            if seen.insert(canon) {
                total += l.weight_elems;
            }
        }
        total
    }

    #[test]
    fn params_in_table1_range() {
        let m = seq2seq_default(1);
        let p = unique_params(&m);
        // Table 1: 100M-1B params
        assert!((90_000_000..1_000_000_000).contains(&p), "{p}");
    }

    #[test]
    fn decoder_gemms_are_tall_skinny() {
        // batch 1, beam 4: decoder GEMM rows = 4 — the Fig-5 triangle zone
        let m = seq2seq_default(1);
        let dec_gates: Vec<_> = m
            .layers
            .iter()
            .filter(|l| l.name.contains("dec.") && l.name.contains("gates"))
            .collect();
        assert!(!dec_gates.is_empty());
        for l in dec_gates {
            assert_eq!(l.gemm.unwrap().m, 4);
        }
    }

    #[test]
    fn recurrent_intensity_in_table1_band() {
        // Table 1: seq2seq intensity 2-20. The band is set by the
        // *decoder* (1-8 effective rows); the encoder batches over all
        // source positions and is naturally denser.
        let m = seq2seq_default(2);
        let dec: Vec<_> = m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Recurrent && l.name.starts_with("dec."))
            .collect();
        assert!(!dec.is_empty());
        for l in dec {
            let i = l.ops_per_weight();
            assert!((2.0..=20.0).contains(&i), "{} intensity {i}", l.name);
        }
    }

    #[test]
    fn lstm_has_more_gate_params_than_gru() {
        let gru = seq2seq_gru(1, 20, 20, 4, 1024, 4, 32_768);
        let lstm = seq2seq_lstm(1, 20, 20, 4, 1024, 4, 32_768);
        let gates = |m: &ModelDesc| -> u64 {
            m.layers
                .iter()
                .filter(|l| l.class == OpClass::Recurrent && l.name.starts_with("enc."))
                .map(|l| l.weight_elems)
                .sum()
        };
        // 4 gates vs 3: exactly 4/3 the recurrent parameters
        let (g, l) = (gates(&gru) as f64, gates(&lstm) as f64);
        assert!((l / g - 4.0 / 3.0).abs() < 0.01, "{l} / {g}");
    }

    #[test]
    fn lstm_decoder_stays_in_table1_intensity_band() {
        let m = seq2seq_lstm(2, 20, 20, 4, 1024, 4, 32_768);
        for l in m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Recurrent && l.name.starts_with("dec."))
        {
            let i = l.ops_per_weight();
            assert!((2.0..=20.0).contains(&i), "{} intensity {i}", l.name);
        }
    }

    #[test]
    fn decode_steps_scale_layers() {
        let short = seq2seq_gru(1, 10, 5, 4, 256, 2, 1000);
        let long = seq2seq_gru(1, 10, 20, 4, 256, 2, 1000);
        assert!(long.layers.len() > short.layers.len());
        assert!(long.flops() > 3 * short.flops() / 2);
    }
}
