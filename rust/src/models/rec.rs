//! Recommendation model descriptor (Fig 2, §2.1.1).
//!
//! Two scales:
//! - [`RecsysScale::Production`]: Table-1 characteristics — FCs with
//!   1-10M params, embedding tables totalling >10B params, batch 1-100,
//!   pooling >10 lookups per bag. Used by the characterization engine.
//! - [`RecsysScale::Servable`]: the scaled-down model the AOT artifacts
//!   actually serve (matches `python/compile/model.py::RecsysConfig`).

use super::{embedding, fc, softmax, tensor_manip, Category, LatencyClass, ModelDesc};

/// Which instantiation of the Fig-2 architecture to describe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecsysScale {
    /// Data-center scale: 48 tables x 7M rows x 32 dims (~10.7B params),
    /// bottom MLP 256->256->128->64, top MLP ->512->256->1.
    Production,
    /// The servable artifact scale (8 tables x 10k rows x 32 dims).
    Servable,
}

/// Build the Fig-2 model descriptor at the given batch size.
pub fn recsys(scale: RecsysScale, batch: u64) -> ModelDesc {
    let (n_tables, rows, dim, pool, dense_dim, bottom, top): (
        u64,
        u64,
        u64,
        u64,
        u64,
        Vec<u64>,
        Vec<u64>,
    ) = match scale {
        RecsysScale::Production => {
            (48, 7_000_000, 32, 40, 256, vec![512, 256, 64], vec![1024, 512, 1])
        }
        RecsysScale::Servable => (8, 10_000, 32, 32, 32, vec![128, 64, 32], vec![256, 128, 1]),
    };

    let mut layers = Vec::new();
    // bottom MLP over dense features
    let mut k = dense_dim;
    for (i, &n) in bottom.iter().enumerate() {
        layers.push(fc(&format!("bottom.fc{i}"), batch, n, k));
        k = n;
    }
    // embedding lookups (SparseLengthsSum per table)
    for t in 0..n_tables {
        layers.push(embedding(&format!("emb.table{t}"), batch, rows, dim, pool));
    }
    // feature interaction: concat pooled embeddings + dense projection
    let interaction = n_tables * dim + k;
    layers.push(tensor_manip("interact.concat", batch * interaction));
    // top MLP to the event-probability head
    let mut k = interaction;
    for (i, &n) in top.iter().enumerate() {
        layers.push(fc(&format!("top.fc{i}"), batch, n, k));
        k = n;
    }
    layers.push(softmax("head.sigmoid", batch));

    ModelDesc {
        name: match scale {
            RecsysScale::Production => format!("recsys_prod_b{batch}"),
            RecsysScale::Servable => format!("recsys_servable_b{batch}"),
        },
        category: Category::Recommendation,
        batch,
        layers,
        latency: LatencyClass::TensMs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::OpClass;

    #[test]
    fn production_embeddings_exceed_10b_params() {
        let m = recsys(RecsysScale::Production, 16);
        let emb: u64 = m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Embedding)
            .map(|l| l.weight_elems)
            .sum();
        assert!(emb > 10_000_000_000, "emb params {emb}"); // Table 1: >10B
    }

    #[test]
    fn production_fc_params_in_table1_range() {
        let m = recsys(RecsysScale::Production, 16);
        let fc_params: u64 = m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Fc)
            .map(|l| l.weight_elems)
            .sum();
        // Table 1: FCs 1-10M params
        assert!((1_000_000..10_000_000).contains(&fc_params), "{fc_params}");
    }

    #[test]
    fn fc_intensity_tracks_batch() {
        // Table 1: FC arithmetic intensity 20-200 for batch 10-100
        for (batch, lo, hi) in [(10u64, 15.0, 25.0), (100, 150.0, 210.0)] {
            let m = recsys(RecsysScale::Production, batch);
            let fc_layers: Vec<_> =
                m.layers.iter().filter(|l| l.class == OpClass::Fc).collect();
            for l in fc_layers {
                let i = l.ops_per_weight();
                assert!(i >= lo && i <= hi, "batch {batch}: intensity {i}");
            }
        }
    }

    #[test]
    fn embedding_intensity_is_1_to_2() {
        let m = recsys(RecsysScale::Production, 16);
        for l in m.layers.iter().filter(|l| l.class == OpClass::Embedding) {
            let i = l.ops_per_weight();
            assert!((0.9..=2.0).contains(&i), "intensity {i}");
        }
    }

    #[test]
    fn servable_matches_python_config() {
        // must agree with python/compile/model.py::RecsysConfig defaults
        let m = recsys(RecsysScale::Servable, 16);
        let emb_layers: Vec<_> =
            m.layers.iter().filter(|l| l.class == OpClass::Embedding).collect();
        assert_eq!(emb_layers.len(), 8);
        assert_eq!(emb_layers[0].weight_elems, 10_000 * 32);
        // param_count matches RecsysConfig.param_count() = 2,891,617..ish
        let p = m.params();
        assert!((2_500_000..3_500_000).contains(&p), "{p}");
    }

    #[test]
    fn dominated_by_embedding_traffic() {
        // §2.1.1: "the overall model's execution tends to be memory
        // bandwidth bound and dominated by the embedding lookups" — at
        // serving batch sizes the pooled-row traffic outgrows the
        // (batch-independent) FC weight traffic
        let m = recsys(RecsysScale::Production, 64);
        let emb_traffic: u64 = m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Embedding)
            .map(|l| l.weight_traffic_elems)
            .sum();
        let fc_traffic: u64 = m
            .layers
            .iter()
            .filter(|l| l.class == OpClass::Fc)
            .map(|l| l.weight_traffic_elems)
            .sum();
        assert!(emb_traffic > fc_traffic, "emb {emb_traffic} fc {fc_traffic}");
    }
}
