//! [`ModelService`] implementations: how each paper model family (§2)
//! plugs into the [`crate::coordinator::ServingFrontend`].
//!
//! Each service pulls its dimensions from the artifact manifest's
//! `models` section at construction time and provides typed request
//! constructors plus a synthetic-load generator, so examples, benches
//! and tests share one definition of each family's wire format:
//!
//! - [`RecSysService`] — Fig-2 recommendation (dense features + pooled
//!   sparse ids -> event probability), `recsys_fp32_b*` artifacts.
//! - [`CvService`]     — image classification (§2.1.2), `cv_tiny_b*`.
//! - [`NmtService`]    — seq2seq GRU decode step (§2.1.3), `gru_step_b*`.
//!
//! All three use the default row-stack/scatter batch layout; a family
//! with ragged inputs would override `assemble`/`scatter`.

use anyhow::{ensure, Context, Result};

use crate::coordinator::request::{InferRequest, SeqRequest};
use crate::coordinator::service::{DeadlineClass, IndexSkew, ModelService};
use crate::models::nmt::SeqDecodeSpec;
use crate::runtime::{DType, HostTensor, Manifest};
use crate::util::rng::Pcg32;

fn check_input(
    req: &InferRequest,
    j: usize,
    dtype: DType,
    shape: &[usize],
) -> Result<()> {
    let t = req.inputs.get(j).with_context(|| format!("request {} missing input {j}", req.id))?;
    ensure!(
        t.dtype == dtype && t.shape == shape,
        "request {} input {j}: got {:?}{:?}, want {:?}{:?}",
        req.id,
        t.dtype,
        t.shape,
        dtype,
        shape
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Recommendation (Fig 2, §2.1.1)
// ---------------------------------------------------------------------------

/// Serves the Fig-2 recommendation model: per-request dense features
/// `[dense_dim]` f32 and pooled sparse ids `[n_tables, pool]` i32.
#[derive(Debug, Clone)]
pub struct RecSysService {
    pub dense_dim: usize,
    pub n_tables: usize,
    pub pool: usize,
    pub rows_per_table: usize,
}

impl RecSysService {
    pub const MODEL_ID: &str = "recsys";
    pub const PREFIX: &str = "recsys_fp32";

    pub fn from_manifest(manifest: &Manifest) -> Result<RecSysService> {
        let cfg = manifest.model_config(Self::MODEL_ID)?;
        Ok(RecSysService {
            dense_dim: cfg.get("dense_dim").as_usize().context("dense_dim")?,
            n_tables: cfg.get("n_tables").as_usize().context("n_tables")?,
            pool: cfg.get("pool").as_usize().context("pool")?,
            rows_per_table: cfg.get("rows_per_table").as_usize().context("rows_per_table")?,
        })
    }

    /// Build a request from raw feature vectors.
    pub fn request(
        &self,
        id: u64,
        dense: Vec<f32>,
        indices: Vec<i32>,
        deadline_ms: f64,
    ) -> Result<InferRequest> {
        ensure!(dense.len() == self.dense_dim, "dense len {} != {}", dense.len(), self.dense_dim);
        ensure!(
            indices.len() == self.n_tables * self.pool,
            "indices len {} != {}",
            indices.len(),
            self.n_tables * self.pool
        );
        Ok(InferRequest::new(
            Self::MODEL_ID,
            id,
            vec![
                HostTensor::from_f32(&[self.dense_dim], &dense),
                HostTensor::from_i32(&[self.n_tables, self.pool], &indices),
            ],
            deadline_ms,
        ))
    }

    /// Synthetic production-like request: N(0,1) dense features and
    /// Zipf-skewed embedding ids (the paper's skewed-access regime).
    pub fn synth_request(&self, id: u64, rng: &mut Pcg32, deadline_ms: f64) -> InferRequest {
        self.synth_request_skewed(id, rng, deadline_ms, IndexSkew::Zipf(1.05))
    }

    /// [`Self::synth_request`] under an explicit id-skew regime
    /// (`loadgen --skew`): uniform for the adversarial cold case, or
    /// any Zipf exponent for hot-head sweeps.
    pub fn synth_request_skewed(
        &self,
        id: u64,
        rng: &mut Pcg32,
        deadline_ms: f64,
        skew: IndexSkew,
    ) -> InferRequest {
        let mut dense = vec![0f32; self.dense_dim];
        rng.fill_normal(&mut dense, 0.0, 1.0);
        let indices: Vec<i32> = (0..self.n_tables * self.pool)
            .map(|_| skew.sample(rng, self.rows_per_table as u32) as i32)
            .collect();
        self.request(id, dense, indices, deadline_ms).expect("synth dims match config")
    }
}

impl ModelService for RecSysService {
    fn model_id(&self) -> &str {
        Self::MODEL_ID
    }

    fn artifact_prefix(&self) -> &str {
        Self::PREFIX
    }

    fn deadline_class(&self) -> DeadlineClass {
        DeadlineClass::Interactive
    }

    fn validate(&self, req: &InferRequest) -> Result<()> {
        ensure!(req.inputs.len() == 2, "expected 2 inputs, got {}", req.inputs.len());
        check_input(req, 0, DType::F32, &[self.dense_dim])?;
        check_input(req, 1, DType::I32, &[self.n_tables, self.pool])
    }

    fn synth_request(&self, id: u64, rng: &mut Pcg32, deadline_ms: f64) -> InferRequest {
        RecSysService::synth_request(self, id, rng, deadline_ms)
    }

    fn synth_request_skewed(
        &self,
        id: u64,
        rng: &mut Pcg32,
        deadline_ms: f64,
        skew: IndexSkew,
    ) -> InferRequest {
        RecSysService::synth_request_skewed(self, id, rng, deadline_ms, skew)
    }
}

// ---------------------------------------------------------------------------
// Computer vision (§2.1.2)
// ---------------------------------------------------------------------------

/// Serves the CV classifier artifacts: per-request image
/// `[channels, in_hw, in_hw]` f32 -> class logits `[classes]`.
#[derive(Debug, Clone)]
pub struct CvService {
    pub in_hw: usize,
    pub channels: usize,
    pub classes: usize,
}

impl CvService {
    pub const MODEL_ID: &str = "cv";
    pub const PREFIX: &str = "cv_tiny";

    pub fn from_manifest(manifest: &Manifest) -> Result<CvService> {
        let cfg = manifest.model_config(Self::MODEL_ID)?;
        Ok(CvService {
            in_hw: cfg.get("in_hw").as_usize().context("in_hw")?,
            channels: cfg.get("channels").as_usize().context("channels")?,
            classes: cfg.get("classes").as_usize().context("classes")?,
        })
    }

    fn image_shape(&self) -> [usize; 3] {
        [self.channels, self.in_hw, self.in_hw]
    }

    pub fn request(&self, id: u64, image: Vec<f32>, deadline_ms: f64) -> Result<InferRequest> {
        let want = self.channels * self.in_hw * self.in_hw;
        ensure!(image.len() == want, "image len {} != {}", image.len(), want);
        Ok(InferRequest::new(
            Self::MODEL_ID,
            id,
            vec![HostTensor::from_f32(&self.image_shape(), &image)],
            deadline_ms,
        ))
    }

    pub fn synth_request(&self, id: u64, rng: &mut Pcg32, deadline_ms: f64) -> InferRequest {
        let mut image = vec![0f32; self.channels * self.in_hw * self.in_hw];
        rng.fill_normal(&mut image, 0.0, 1.0);
        self.request(id, image, deadline_ms).expect("synth dims match config")
    }
}

impl ModelService for CvService {
    fn model_id(&self) -> &str {
        Self::MODEL_ID
    }

    fn artifact_prefix(&self) -> &str {
        Self::PREFIX
    }

    fn deadline_class(&self) -> DeadlineClass {
        DeadlineClass::Relaxed
    }

    fn validate(&self, req: &InferRequest) -> Result<()> {
        ensure!(req.inputs.len() == 1, "expected 1 input, got {}", req.inputs.len());
        check_input(req, 0, DType::F32, &self.image_shape())
    }

    fn synth_request(&self, id: u64, rng: &mut Pcg32, deadline_ms: f64) -> InferRequest {
        CvService::synth_request(self, id, rng, deadline_ms)
    }
}

// ---------------------------------------------------------------------------
// NMT decode step (§2.1.3)
// ---------------------------------------------------------------------------

/// Serves the seq2seq GRU decode-step artifacts: per-request embedded
/// token `x [hidden]` and decoder state `h [hidden]` -> vocab logits
/// `[vocab]` and new state `[hidden]` (the beam-search inner loop).
///
/// The per-step request path above is what the batch-inference plane
/// serves; the sequence plane ([`crate::coordinator::seqserve`]) runs
/// whole decodes server-side against the same artifacts, following
/// [`SeqDecodeSpec`] (from [`NmtService::decode_spec`]).
#[derive(Debug, Clone)]
pub struct NmtService {
    pub hidden: usize,
    pub vocab: usize,
    /// token id that ends a sequence early (manifest `eos`, default 0)
    pub eos: u32,
}

impl NmtService {
    pub const MODEL_ID: &str = "nmt";
    /// Manifest `models` key of the decode-step artifacts.
    pub const CONFIG_KEY: &str = "gru";
    pub const PREFIX: &str = "gru_step";

    pub fn from_manifest(manifest: &Manifest) -> Result<NmtService> {
        let cfg = manifest.model_config(Self::CONFIG_KEY)?;
        Ok(NmtService {
            hidden: cfg.get("hidden").as_usize().context("hidden")?,
            vocab: cfg.get("vocab").as_usize().context("vocab")?,
            // optional so pre-seq-plane manifests keep loading
            eos: cfg.get("eos").as_usize().map(|e| e as u32).unwrap_or(0),
        })
    }

    /// The greedy decode semantics of this family's artifacts.
    pub fn decode_spec(&self) -> SeqDecodeSpec {
        SeqDecodeSpec { hidden: self.hidden, vocab: self.vocab, eos: self.eos }
    }

    /// Build a whole-sequence request from an initial embedded token
    /// and decoder state (the sequence plane's submit unit).
    pub fn seq_request(
        &self,
        id: u64,
        x0: Vec<f32>,
        h0: Vec<f32>,
        max_len: u32,
        deadline_ms: f64,
    ) -> Result<SeqRequest> {
        ensure!(x0.len() == self.hidden, "x0 len {} != {}", x0.len(), self.hidden);
        ensure!(h0.len() == self.hidden, "h0 len {} != {}", h0.len(), self.hidden);
        ensure!(max_len >= 1, "max_len must be >= 1");
        Ok(SeqRequest::new(
            Self::MODEL_ID,
            id,
            vec![
                HostTensor::from_f32(&[self.hidden], &x0),
                HostTensor::from_f32(&[self.hidden], &h0),
            ],
            max_len,
            deadline_ms,
        ))
    }

    /// Synthetic sequence request with a reproducible per-id state
    /// (seeded by `seed ^ id`), so a loadgen client and a reference
    /// decoder can regenerate the identical initial state.
    pub fn synth_seq_request(
        &self,
        id: u64,
        seed: u64,
        max_len: u32,
        deadline_ms: f64,
    ) -> SeqRequest {
        let (x0, h0) = self.synth_seq_state(id, seed);
        self.seq_request(id, x0, h0, max_len, deadline_ms).expect("synth dims match config")
    }

    /// The `(x0, h0)` pair [`Self::synth_seq_request`] embeds.
    pub fn synth_seq_state(&self, id: u64, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::new(seed ^ id, id.wrapping_add(77));
        let mut x0 = vec![0f32; self.hidden];
        let mut h0 = vec![0f32; self.hidden];
        rng.fill_normal(&mut x0, 0.0, 1.0);
        rng.fill_normal(&mut h0, 0.0, 0.5);
        (x0, h0)
    }

    pub fn request(&self, id: u64, x: Vec<f32>, h: Vec<f32>, deadline_ms: f64) -> Result<InferRequest> {
        ensure!(x.len() == self.hidden, "x len {} != {}", x.len(), self.hidden);
        ensure!(h.len() == self.hidden, "h len {} != {}", h.len(), self.hidden);
        Ok(InferRequest::new(
            Self::MODEL_ID,
            id,
            vec![
                HostTensor::from_f32(&[self.hidden], &x),
                HostTensor::from_f32(&[self.hidden], &h),
            ],
            deadline_ms,
        ))
    }

    pub fn synth_request(&self, id: u64, rng: &mut Pcg32, deadline_ms: f64) -> InferRequest {
        let mut x = vec![0f32; self.hidden];
        let mut h = vec![0f32; self.hidden];
        rng.fill_normal(&mut x, 0.0, 1.0);
        rng.fill_normal(&mut h, 0.0, 0.5);
        self.request(id, x, h, deadline_ms).expect("synth dims match config")
    }
}

impl ModelService for NmtService {
    fn model_id(&self) -> &str {
        Self::MODEL_ID
    }

    fn artifact_prefix(&self) -> &str {
        Self::PREFIX
    }

    fn deadline_class(&self) -> DeadlineClass {
        DeadlineClass::Interactive
    }

    fn validate(&self, req: &InferRequest) -> Result<()> {
        ensure!(req.inputs.len() == 2, "expected 2 inputs, got {}", req.inputs.len());
        check_input(req, 0, DType::F32, &[self.hidden])?;
        check_input(req, 1, DType::F32, &[self.hidden])
    }

    fn synth_request(&self, id: u64, rng: &mut Pcg32, deadline_ms: f64) -> InferRequest {
        NmtService::synth_request(self, id, rng, deadline_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{scatter_rows, stack_rows};
    use std::path::Path;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "recsys": {"dense_dim": 4, "n_tables": 2, "pool": 3, "rows_per_table": 100},
        "gru": {"hidden": 8, "vocab": 16},
        "cv": {"in_hw": 4, "channels": 1, "classes": 3}
      },
      "artifacts": {}
    }"#;

    fn manifest() -> Manifest {
        Manifest::parse(Path::new("."), SAMPLE).unwrap()
    }

    #[test]
    fn recsys_assemble_scatter_round_trip() {
        let svc = RecSysService::from_manifest(&manifest()).unwrap();
        assert_eq!(svc.model_id(), "recsys");
        let mut rng = Pcg32::seeded(1);
        let reqs: Vec<_> = (0..3).map(|i| svc.synth_request(i, &mut rng, 100.0)).collect();
        for r in &reqs {
            svc.validate(r).unwrap();
        }
        let batch = svc.assemble(&reqs, 4).unwrap();
        assert_eq!(batch[0].shape, vec![4, 4]); // [variant, dense_dim]
        assert_eq!(batch[1].shape, vec![4, 2, 3]); // [variant, n_tables, pool]
        // padded tail row is zeros (id 0 lookups — harmless, discarded)
        let idx = batch[1].as_i32().unwrap();
        assert!(idx[3 * 6..].iter().all(|&v| v == 0));
        // round trip: each request's rows come back out
        let rows = scatter_rows(&batch, reqs.len()).unwrap();
        for (r, row) in reqs.iter().zip(&rows) {
            assert_eq!(row[0].data, r.inputs[0].data);
            assert_eq!(row[1].data, r.inputs[1].data);
        }
    }

    #[test]
    fn recsys_validate_rejects_wrong_shapes() {
        let svc = RecSysService::from_manifest(&manifest()).unwrap();
        assert!(svc.request(0, vec![0.0; 3], vec![0; 6], 100.0).is_err());
        assert!(svc.request(0, vec![0.0; 4], vec![0; 5], 100.0).is_err());
        let ok = svc.request(0, vec![0.0; 4], vec![0; 6], 100.0).unwrap();
        svc.validate(&ok).unwrap();
        // a foreign request shape fails validation
        let bad = InferRequest::new("recsys", 1, vec![HostTensor::from_f32(&[4], &[0.0; 4])], 1.0);
        assert!(svc.validate(&bad).is_err());
    }

    #[test]
    fn nmt_assemble_pads_both_state_tensors() {
        let svc = NmtService::from_manifest(&manifest()).unwrap();
        assert_eq!(svc.artifact_prefix(), "gru_step");
        let mut rng = Pcg32::seeded(2);
        let reqs: Vec<_> = (0..2).map(|i| svc.synth_request(i, &mut rng, 50.0)).collect();
        let batch = svc.assemble(&reqs, 8).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].shape, vec![8, 8]);
        assert_eq!(batch[1].shape, vec![8, 8]);
        // decode-step outputs scatter to [vocab] and [hidden] per request
        let outs = vec![
            HostTensor::from_f32(&[8, 16], &[0.5; 8 * 16]),
            HostTensor::from_f32(&[8, 8], &[0.25; 64]),
        ];
        let rows = svc.scatter(&outs, 2).unwrap();
        assert_eq!(rows[0][0].shape, vec![16]);
        assert_eq!(rows[0][1].shape, vec![8]);
    }

    #[test]
    fn cv_round_trip_and_deadline_class() {
        let svc = CvService::from_manifest(&manifest()).unwrap();
        assert_eq!(svc.deadline_class(), DeadlineClass::Relaxed);
        let mut rng = Pcg32::seeded(3);
        let reqs: Vec<_> = (0..2).map(|i| svc.synth_request(i, &mut rng, 0.0)).collect();
        svc.validate(&reqs[0]).unwrap();
        let batch = stack_rows(&reqs, 2).unwrap();
        assert_eq!(batch[0].shape, vec![2, 1, 4, 4]);
        let logits = vec![HostTensor::from_f32(&[2, 3], &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0])];
        let rows = svc.scatter(&logits, 2).unwrap();
        assert_eq!(rows[1][0].as_f32().unwrap(), vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn nmt_seq_requests_validate_and_eos_defaults() {
        // manifest without `eos` (pre-sequence-plane): defaults to 0
        let svc = NmtService::from_manifest(&manifest()).unwrap();
        assert_eq!(svc.eos, 0);
        assert_eq!(svc.decode_spec(), SeqDecodeSpec { hidden: 8, vocab: 16, eos: 0 });
        let m = Manifest::parse(
            Path::new("."),
            r#"{"version": 1, "models": {"gru": {"hidden": 8, "vocab": 16, "eos": 3}}, "artifacts": {}}"#,
        )
        .unwrap();
        assert_eq!(NmtService::from_manifest(&m).unwrap().eos, 3);
        // seq_request validates dimensions and the length cap
        assert!(svc.seq_request(1, vec![0.0; 7], vec![0.0; 8], 4, 0.0).is_err());
        assert!(svc.seq_request(1, vec![0.0; 8], vec![0.0; 9], 4, 0.0).is_err());
        assert!(svc.seq_request(1, vec![0.0; 8], vec![0.0; 8], 0, 0.0).is_err());
        let req = svc.seq_request(1, vec![0.0; 8], vec![0.0; 8], 4, 25.0).unwrap();
        assert_eq!(req.model, "nmt");
        assert_eq!(req.max_len, 4);
        assert_eq!(req.inputs.len(), 2);
        // synth state is reproducible per (seed, id) and id-keyed
        let (x0, h0) = svc.synth_seq_state(9, 0xabc);
        let (x1, h1) = svc.synth_seq_state(9, 0xabc);
        assert_eq!((x0.clone(), h0.clone()), (x1, h1));
        let (x2, _) = svc.synth_seq_state(10, 0xabc);
        assert_ne!(x0, x2);
    }

    #[test]
    fn missing_model_config_errors() {
        let m = Manifest::parse(
            Path::new("."),
            r#"{"version": 1, "models": {}, "artifacts": {}}"#,
        )
        .unwrap();
        assert!(RecSysService::from_manifest(&m).is_err());
        assert!(CvService::from_manifest(&m).is_err());
        assert!(NmtService::from_manifest(&m).is_err());
    }
}
