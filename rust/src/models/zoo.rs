//! The representative zoo: the exact model set of Table 1, with the
//! typical batch sizes the paper reports, used by the characterization
//! engine, the roofline study (Fig 3) and the fleet simulator (Fig 4).

use super::cv::{faster_rcnn_shuffle, resnet50, resnext101, resnext3d_101};
use super::nmt::seq2seq_default;
use super::rec::{recsys, RecsysScale};
use super::ModelDesc;

/// A zoo entry: the model descriptor plus its fleet-mix weight (the
/// relative share of inference demand it receives in the simulator;
/// calibrated so the Fig-4 op-time breakdown lands near the paper's).
#[derive(Debug, Clone)]
pub struct ZooEntry {
    pub desc: ModelDesc,
    pub fleet_weight: f64,
}

/// Build the full Table-1 zoo.
pub fn representative_zoo() -> Vec<ZooEntry> {
    vec![
        // Recommendation dominates data-center inference demand (Fig 1):
        // ads + feed ranking at several batch sizes.
        ZooEntry { desc: recsys(RecsysScale::Production, 1), fleet_weight: 0.10 },
        ZooEntry { desc: recsys(RecsysScale::Production, 16), fleet_weight: 0.25 },
        ZooEntry { desc: recsys(RecsysScale::Production, 64), fleet_weight: 0.25 },
        // CV content understanding
        ZooEntry { desc: resnet50(1), fleet_weight: 0.08 },
        ZooEntry { desc: resnext101(1, 4), fleet_weight: 0.07 },
        ZooEntry { desc: resnext101(1, 48), fleet_weight: 0.02 },
        ZooEntry { desc: faster_rcnn_shuffle(50), fleet_weight: 0.06 },
        ZooEntry { desc: resnext3d_101(16), fleet_weight: 0.04 },
        // NMT
        ZooEntry { desc: seq2seq_default(1), fleet_weight: 0.08 },
        ZooEntry { desc: seq2seq_default(8), fleet_weight: 0.05 },
    ]
}

/// Find a zoo entry by model name prefix.
pub fn zoo_entry(name: &str) -> Option<ZooEntry> {
    representative_zoo().into_iter().find(|e| e.desc.name.starts_with(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Category;

    #[test]
    fn zoo_covers_all_categories() {
        let zoo = representative_zoo();
        for cat in [Category::Recommendation, Category::ComputerVision, Category::Language] {
            assert!(zoo.iter().any(|e| e.desc.category == cat), "{cat:?} missing");
        }
        assert!(zoo.len() >= 8);
    }

    #[test]
    fn fleet_weights_sum_to_one() {
        let total: f64 = representative_zoo().iter().map(|e| e.fleet_weight).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn lookup_by_prefix() {
        assert!(zoo_entry("resnet50").is_some());
        assert!(zoo_entry("seq2seq").is_some());
        assert!(zoo_entry("nope").is_none());
    }

    #[test]
    fn every_model_has_layers_and_flops() {
        for e in representative_zoo() {
            assert!(!e.desc.layers.is_empty(), "{}", e.desc.name);
            assert!(e.desc.flops() > 0, "{}", e.desc.name);
        }
    }
}
