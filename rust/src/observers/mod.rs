//! Fleet-wide DL inference profiling (§3.1): the observer software
//! design pattern applied to individual operators, the per-op cost
//! inference functions, and the analytical roofline prediction each
//! observation is compared against.
//!
//! "We have implemented the observer software design pattern that can
//! be applied to individual operators and are executed at the start and
//! end of the operator... a telemetry agent running on each host
//! collects and compares this information with given predictions."

use std::time::Instant;

use crate::models::Layer;
use crate::perfmodel::DeviceSpec;

/// One completed operator observation.
#[derive(Debug, Clone)]
pub struct OpRecord {
    pub model: String,
    pub op_name: String,
    pub bucket: &'static str,
    pub wall_us: f64,
    pub flops: u64,
    pub bytes: u64,
    /// analytical roofline prediction for the host device (us)
    pub predicted_us: f64,
}

impl OpRecord {
    /// Attained compute throughput (Gop/s).
    pub fn attained_gops(&self) -> f64 {
        self.flops as f64 / (self.wall_us * 1e3)
    }

    /// Attained bandwidth (GB/s).
    pub fn attained_gbps(&self) -> f64 {
        self.bytes as f64 / (self.wall_us * 1e3)
    }

    /// measured / predicted: ~1 means the roofline is accurate; >>1
    /// flags an inefficiency worth optimizing (§3.1's priority signal).
    pub fn inefficiency(&self) -> f64 {
        self.wall_us / self.predicted_us.max(1e-9)
    }
}

/// Cost-inference function (the Caffe2 operator cost inference): the
/// analytical flops/bytes of one layer at a serving dtype.
pub fn cost_inference(l: &Layer, elem_bytes: u64) -> (u64, u64) {
    let bytes = (l.weight_traffic_elems + l.act_in_elems + l.act_out_elems) * elem_bytes;
    (l.flops, bytes)
}

/// Roofline prediction in microseconds.
pub fn predict_us(flops: u64, bytes: u64, dev: &DeviceSpec) -> f64 {
    let t_c = flops as f64 / dev.peak_ops;
    let t_m = bytes as f64 / dev.dram_bw;
    t_c.max(t_m) * 1e6
}

/// RAII observer: times an operator execution and produces an
/// [`OpRecord`] on drop-by-finish.
pub struct OpObserver<'a> {
    model: &'a str,
    layer: &'a Layer,
    dev: &'a DeviceSpec,
    elem_bytes: u64,
    start: Instant,
}

impl<'a> OpObserver<'a> {
    pub fn start(model: &'a str, layer: &'a Layer, dev: &'a DeviceSpec, elem_bytes: u64) -> Self {
        OpObserver { model, layer, dev, elem_bytes, start: Instant::now() }
    }

    pub fn finish(self) -> OpRecord {
        let wall_us = self.start.elapsed().as_secs_f64() * 1e6;
        self.record(wall_us)
    }

    /// For the fleet *simulator*: record with a synthetic wall time.
    pub fn record(&self, wall_us: f64) -> OpRecord {
        let (flops, bytes) = cost_inference(self.layer, self.elem_bytes);
        OpRecord {
            model: self.model.to_string(),
            op_name: self.layer.name.clone(),
            bucket: self.layer.class.bucket(),
            wall_us,
            flops,
            bytes,
            predicted_us: predict_us(flops, bytes, self.dev),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fc;

    fn dev() -> DeviceSpec {
        DeviceSpec::xeon_fp32()
    }

    #[test]
    fn cost_inference_counts_traffic() {
        let l = fc("fc", 4, 16, 32);
        let (flops, bytes) = cost_inference(&l, 4);
        assert_eq!(flops, 2 * 4 * 16 * 32);
        assert_eq!(bytes, ((16 * 32 + 16) + 4 * 32 + 4 * 16) * 4);
    }

    #[test]
    fn prediction_is_roofline_max() {
        let d = dev();
        // compute bound case
        let t1 = predict_us(10_000_000_000, 8, &d);
        assert!((t1 - 10e9 / d.peak_ops * 1e6).abs() < 1e-9);
        // memory bound case
        let t2 = predict_us(8, 10_000_000_000, &d);
        assert!((t2 - 10e9 / d.dram_bw * 1e6).abs() < 1e-6);
    }

    #[test]
    fn observer_times_execution() {
        let l = fc("fc", 4, 16, 32);
        let d = dev();
        let obs = OpObserver::start("m", &l, &d, 4);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let rec = obs.finish();
        assert!(rec.wall_us >= 1500.0, "{}", rec.wall_us);
        assert_eq!(rec.bucket, "FC");
        assert!(rec.inefficiency() > 1.0); // slept way over prediction
    }

    #[test]
    fn synthetic_record_uses_given_time() {
        let l = fc("fc", 4, 16, 32);
        let d = dev();
        let obs = OpObserver::start("m", &l, &d, 4);
        let rec = obs.record(123.0);
        assert_eq!(rec.wall_us, 123.0);
        assert!(rec.attained_gops() > 0.0);
        assert!(rec.attained_gbps() > 0.0);
    }
}
