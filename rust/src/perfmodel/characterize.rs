//! Table-1 characterization engine: compute the paper's resource-
//! requirement columns from the model descriptors.

use crate::models::{Category, LatencyClass, ModelDesc, OpClass};

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct CharacterizationRow {
    pub model: String,
    pub category: Category,
    pub batch: u64,
    pub params: u64,
    pub max_live_acts: u64,
    pub intensity_w_avg: f64,
    pub intensity_w_min: f64,
    pub intensity_full_avg: f64,
    pub intensity_full_min: f64,
    pub latency: LatencyClass,
}

/// Characterize one model.
///
/// Table-1 convention: for CV models the per-layer *min* intensity is
/// taken over the convolutional trunk (the paper reports min 100 for
/// ResNet-50, which excludes the 1000-way classifier FC whose batch-1
/// ops/weight is ~2 — the trunk is what the min column is about).
pub fn characterize(m: &ModelDesc) -> CharacterizationRow {
    let trunk_only = m.category == Category::ComputerVision;
    let min_w = m
        .layers
        .iter()
        .filter(|l| l.weight_traffic_elems > 0 && !(trunk_only && l.class == OpClass::Fc))
        .map(|l| l.ops_per_weight())
        .fold(f64::INFINITY, f64::min);
    let min_full = m
        .layers
        .iter()
        .filter(|l| l.weight_traffic_elems > 0 && !(trunk_only && l.class == OpClass::Fc))
        .map(|l| l.ops_per_elem())
        .fold(f64::INFINITY, f64::min);
    // Average intensities count the *weighted* layers (convs/FCs/
    // embeddings); elementwise and data-movement ops are assumed fused
    // into their producers, matching how Table 1 reaches e.g. avg 164
    // ops/element for ResNet-50.
    let flops: u64 = m.layers.iter().filter(|l| l.weight_traffic_elems > 0).map(|l| l.flops).sum();
    let w_traffic: u64 = m.layers.iter().map(|l| l.weight_traffic_elems).sum();
    // each activation tensor is counted once (a layer's input is its
    // producer's output), plus the model input
    let full_traffic: u64 = m
        .layers
        .iter()
        .filter(|l| l.weight_traffic_elems > 0)
        .map(|l| l.weight_traffic_elems + l.act_out_elems)
        .sum::<u64>()
        + m.layers.first().map(|l| l.act_in_elems).unwrap_or(0);
    CharacterizationRow {
        model: m.name.clone(),
        category: m.category,
        batch: m.batch,
        params: m.unique_params(),
        max_live_acts: m.max_live_activations(),
        intensity_w_avg: flops as f64 / w_traffic.max(1) as f64,
        intensity_w_min: min_w,
        intensity_full_avg: flops as f64 / full_traffic.max(1) as f64,
        intensity_full_min: min_full,
        latency: m.latency,
    }
}

/// Characterize a set of models (Table 1 regeneration).
pub fn characterize_zoo(models: &[ModelDesc]) -> Vec<CharacterizationRow> {
    models.iter().map(characterize).collect()
}

/// Split a recsys model row into the paper's FC / embedding sub-rows.
pub fn recsys_subrows(m: &ModelDesc) -> (CharacterizationRow, CharacterizationRow) {
    let fc_layers: Vec<_> =
        m.layers.iter().filter(|l| l.class == OpClass::Fc).cloned().collect();
    let emb_layers: Vec<_> =
        m.layers.iter().filter(|l| l.class == OpClass::Embedding).cloned().collect();
    let sub = |name: &str, layers: Vec<crate::models::Layer>| ModelDesc {
        name: format!("{}/{}", m.name, name),
        category: m.category,
        batch: m.batch,
        layers,
        latency: m.latency,
    };
    (characterize(&sub("fc", fc_layers)), characterize(&sub("embedding", emb_layers)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{recsys, representative_zoo, resnet50, RecsysScale};

    #[test]
    fn resnet50_row_matches_table1() {
        let row = characterize(&resnet50(1));
        // Table 1: 25M params, 2M acts, avg 303 / min 100 ops per weight,
        // avg 164 / min 25 ops per element
        assert!((24e6..27e6).contains(&(row.params as f64)));
        assert!((1e6..4e6).contains(&(row.max_live_acts as f64)));
        assert!((250.0..360.0).contains(&row.intensity_w_avg), "{}", row.intensity_w_avg);
        assert!((50.0..150.0).contains(&row.intensity_w_min), "{}", row.intensity_w_min);
        assert!((120.0..240.0).contains(&row.intensity_full_avg), "{}", row.intensity_full_avg); // paper: 164 (activation-accounting convention differs slightly)
        assert!(row.intensity_full_min < 80.0, "{}", row.intensity_full_min); // paper: 25 — well below the avg either way
    }

    #[test]
    fn recsys_subrows_match_table1_bands() {
        let m = recsys(RecsysScale::Production, 64);
        let (fc, emb) = recsys_subrows(&m);
        // FC: 1-10M params, intensity 20-200 band at batch 64
        assert!((1e6..10e6).contains(&(fc.params as f64)));
        assert!((20.0..200.0).contains(&fc.intensity_w_avg), "{}", fc.intensity_w_avg);
        // Embeddings: >10B params, intensity 1-2
        assert!(emb.params > 10_000_000_000);
        assert!((0.9..2.0).contains(&emb.intensity_w_avg), "{}", emb.intensity_w_avg);
    }

    #[test]
    fn zoo_characterization_is_complete() {
        let zoo = representative_zoo();
        let models: Vec<_> = zoo.into_iter().map(|e| e.desc).collect();
        let rows = characterize_zoo(&models);
        assert_eq!(rows.len(), models.len());
        for r in &rows {
            assert!(r.params > 0, "{}", r.model);
            assert!(r.intensity_w_avg.is_finite(), "{}", r.model);
        }
    }
}
