//! Device specifications for the roofline studies.

/// A (possibly hypothetical) inference device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// peak compute throughput, ops/s (int8 ops for the Fig-3 device)
    pub peak_ops: f64,
    /// off-chip (DRAM) bandwidth, bytes/s
    pub dram_bw: f64,
    /// on-chip memory capacity, bytes
    pub onchip_capacity: f64,
    /// on-chip memory bandwidth, bytes/s
    pub onchip_bw: f64,
    /// bytes per model parameter (Fig 3 assumes int8 storage)
    pub weight_bytes_per_elem: f64,
    /// bytes per activation element
    pub act_bytes_per_elem: f64,
}

impl DeviceSpec {
    /// The Fig-3 hypothetical accelerator: 100 TOP/s, 100 GB/s DRAM,
    /// parameters stored as int8. Capacity/on-chip bandwidth are the
    /// figure's sweep axes.
    pub fn fig3(onchip_capacity_mb: f64, onchip_tb_s: f64) -> DeviceSpec {
        DeviceSpec {
            name: "hypothetical-100TOPs",
            peak_ops: 100e12,
            dram_bw: 100e9,
            onchip_capacity: onchip_capacity_mb * 1e6,
            onchip_bw: onchip_tb_s * 1e12,
            weight_bytes_per_elem: 1.0, // int8
            act_bytes_per_elem: 1.0,    // int8 activations
        }
    }

    /// A server CPU in the spirit of the paper's Xeon testbed
    /// (per-socket peak fp32 FLOPs and measured STREAM-ish bandwidth).
    pub fn xeon_fp32() -> DeviceSpec {
        DeviceSpec {
            name: "xeon-fp32",
            peak_ops: 1.0e12,
            dram_bw: 60e9,
            onchip_capacity: 35e6, // LLC
            onchip_bw: 400e9,
            weight_bytes_per_elem: 4.0,
            act_bytes_per_elem: 4.0,
        }
    }

    /// Compute-to-bandwidth "ridge point" in ops/byte for off-chip.
    pub fn ridge(&self) -> f64 {
        self.peak_ops / self.dram_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_device_numbers() {
        let d = DeviceSpec::fig3(10.0, 1.0);
        assert_eq!(d.peak_ops, 100e12);
        assert_eq!(d.dram_bw, 100e9);
        assert_eq!(d.onchip_capacity, 10e6);
        assert_eq!(d.onchip_bw, 1e12);
        // ridge: 1000 ops/byte — why embeddings (intensity 1-2) are
        // hopeless off-chip and the paper wants big on-chip memories
        assert_eq!(d.ridge(), 1000.0);
    }
}
