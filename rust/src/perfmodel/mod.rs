//! Analytical performance models: per-layer rooflines, the hypothetical
//! accelerator of Fig 3 with its greedy on-chip memory allocator, the
//! Table-1 characterization engine, and the Fig-5 matrix-shape survey.

pub mod characterize;
pub mod device;
pub mod roofline;
pub mod shapes;

pub use characterize::{characterize, characterize_zoo, CharacterizationRow};
pub use device::DeviceSpec;
pub use roofline::{roofline_curve, roofline_model, roofline_model_with_policy, AllocPolicy, LayerPlacement, RooflineResult};
pub use shapes::{shape_survey, ShapePoint};
