//! Per-layer roofline model with greedy on-chip memory allocation
//! (Fig 3; the paper's footnote-3 methodology, after Williams et al.
//! [72]).
//!
//! Each layer reads its weights and activations from either on-chip or
//! off-chip memory. A simple greedy allocator assigns the on-chip
//! capacity to the tensors with the highest traffic-per-byte (so a
//! weight tensor reused across the batch, or a small hot activation,
//! wins over a huge cold embedding table). Layer time is then
//!
//! ```text
//! t = max(flops / peak_ops,
//!         offchip_bytes / dram_bw,
//!         onchip_bytes / onchip_bw)
//! ```
//!
//! and the model's achieved performance is `total_flops / sum(t)`.

use crate::models::ModelDesc;

use super::device::DeviceSpec;

/// Where a layer's operand set was placed by the allocator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerPlacement {
    pub weights_onchip: bool,
    pub acts_onchip: bool,
}

/// Result of evaluating one model on one device configuration.
#[derive(Debug, Clone)]
pub struct RooflineResult {
    pub model: String,
    pub achieved_ops: f64,
    pub total_time_s: f64,
    pub placements: Vec<LayerPlacement>,
    /// fraction of layer time spent bandwidth-bound (off-chip)
    pub dram_bound_frac: f64,
}

struct Candidate {
    layer: usize,
    is_weight: bool,
    bytes: f64,
    traffic: f64,
}

/// On-chip allocation policy (the DESIGN.md ablation knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// greedy by traffic-saved per byte (the paper's footnote-3 greedy)
    GreedyValue,
    /// weights first (model-pinning, Brainwave-style), layer order
    WeightsFirst,
    /// activations first, layer order
    ActivationsFirst,
}

/// Evaluate `model` on `dev`, greedily allocating on-chip capacity.
pub fn roofline_model(model: &ModelDesc, dev: &DeviceSpec) -> RooflineResult {
    roofline_model_with_policy(model, dev, AllocPolicy::GreedyValue)
}

/// Evaluate with an explicit allocation policy.
pub fn roofline_model_with_policy(
    model: &ModelDesc,
    dev: &DeviceSpec,
    policy: AllocPolicy,
) -> RooflineResult {
    // Build allocation candidates: per layer, the weight set and the
    // activation set (in + out).
    let mut cands = Vec::new();
    for (i, l) in model.layers.iter().enumerate() {
        if l.weight_elems > 0 {
            cands.push(Candidate {
                layer: i,
                is_weight: true,
                // capacity cost: the whole resident tensor
                bytes: l.weight_elems as f64 * dev.weight_bytes_per_elem,
                // traffic avoided per evaluation
                traffic: l.weight_traffic_elems as f64 * dev.weight_bytes_per_elem,
            });
        }
        let act_elems = l.act_in_elems + l.act_out_elems;
        if act_elems > 0 {
            cands.push(Candidate {
                layer: i,
                is_weight: false,
                bytes: act_elems as f64 * dev.act_bytes_per_elem,
                traffic: act_elems as f64 * dev.act_bytes_per_elem,
            });
        }
    }
    match policy {
        // Greedy: best traffic-saved per byte of capacity first.
        AllocPolicy::GreedyValue => cands.sort_by(|a, b| {
            let va = a.traffic / a.bytes;
            let vb = b.traffic / b.bytes;
            vb.partial_cmp(&va).unwrap().then_with(|| a.bytes.partial_cmp(&b.bytes).unwrap())
        }),
        // Pin weights in layer order, then activations.
        AllocPolicy::WeightsFirst => {
            cands.sort_by_key(|c| (!c.is_weight, c.layer));
        }
        // Pin activations in layer order, then weights.
        AllocPolicy::ActivationsFirst => {
            cands.sort_by_key(|c| (c.is_weight, c.layer));
        }
    }

    let mut placements =
        vec![LayerPlacement { weights_onchip: false, acts_onchip: false }; model.layers.len()];
    let mut remaining = dev.onchip_capacity;
    for c in &cands {
        if c.bytes <= remaining {
            remaining -= c.bytes;
            if c.is_weight {
                placements[c.layer].weights_onchip = true;
            } else {
                placements[c.layer].acts_onchip = true;
            }
        }
    }

    // Per-layer roofline.
    let mut total_time = 0.0;
    let mut dram_time = 0.0;
    for (l, p) in model.layers.iter().zip(&placements) {
        let w_bytes = l.weight_traffic_elems as f64 * dev.weight_bytes_per_elem;
        let a_bytes = (l.act_in_elems + l.act_out_elems) as f64 * dev.act_bytes_per_elem;
        let (mut off, mut on) = (0.0, 0.0);
        if p.weights_onchip {
            on += w_bytes;
        } else {
            off += w_bytes;
        }
        if p.acts_onchip {
            on += a_bytes;
        } else {
            off += a_bytes;
        }
        let t_compute = l.flops as f64 / dev.peak_ops;
        let t_off = off / dev.dram_bw;
        let t_on = on / dev.onchip_bw;
        let t = t_compute.max(t_off).max(t_on);
        total_time += t;
        if t_off >= t_compute && t_off >= t_on {
            dram_time += t;
        }
    }

    RooflineResult {
        model: model.name.clone(),
        achieved_ops: model.flops() as f64 / total_time.max(1e-30),
        total_time_s: total_time,
        placements,
        dram_bound_frac: dram_time / total_time.max(1e-30),
    }
}

/// The Fig-3 sweep: achieved TOP/s vs on-chip capacity for one on-chip
/// bandwidth. Returns (capacity_MB, achieved_TOPs) points.
pub fn roofline_curve(
    model: &ModelDesc,
    capacities_mb: &[f64],
    onchip_tb_s: f64,
) -> Vec<(f64, f64)> {
    capacities_mb
        .iter()
        .map(|&mb| {
            let dev = DeviceSpec::fig3(mb, onchip_tb_s);
            let r = roofline_model(model, &dev);
            (mb, r.achieved_ops / 1e12)
        })
        .collect()
}

/// Standard Fig-3 capacity sweep (x axis).
pub fn fig3_capacities() -> Vec<f64> {
    vec![0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 60.0, 128.0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{recsys, resnet50, resnext101, RecsysScale};

    #[test]
    fn more_onchip_capacity_never_hurts() {
        let m = resnet50(1);
        let mut last = 0.0;
        for (_, tops) in roofline_curve(&m, &fig3_capacities(), 1.0) {
            assert!(tops >= last - 1e-9, "performance regressed: {tops} < {last}");
            last = tops;
        }
    }

    #[test]
    fn higher_onchip_bw_never_hurts() {
        let m = resnext101(1, 4);
        let c1 = roofline_curve(&m, &fig3_capacities(), 1.0);
        let c10 = roofline_curve(&m, &fig3_capacities(), 10.0);
        for ((_, a), (_, b)) in c1.iter().zip(&c10) {
            assert!(b >= a);
        }
    }

    #[test]
    fn perf_bounded_by_peak() {
        let m = resnet50(1);
        for (_, tops) in roofline_curve(&m, &fig3_capacities(), 10.0) {
            assert!(tops <= 100.0 + 1e-9);
        }
    }

    #[test]
    fn zero_capacity_is_dram_bound() {
        // With no on-chip memory everything streams from DRAM at
        // 100 GB/s; a conv model achieves at most
        // dram_bw * avg_intensity ops/s, far below peak.
        let m = resnet50(1);
        let dev = DeviceSpec::fig3(0.0, 1.0);
        let r = roofline_model(&m, &dev);
        assert!(r.achieved_ops < 60e12);
        assert!(r.dram_bound_frac > 0.5);
    }

    #[test]
    fn recommendation_needs_capacity_not_just_bandwidth() {
        // Production recsys embeddings (>10 GB) can never fit on-chip:
        // even at 128 MB the model stays DRAM-bound (the paper's point
        // that recommendation needs memory *capacity and bandwidth*).
        let m = recsys(RecsysScale::Production, 16);
        let dev = DeviceSpec::fig3(128.0, 10.0);
        let r = roofline_model(&m, &dev);
        assert!(r.dram_bound_frac > 0.4, "{}", r.dram_bound_frac);
        // and its achieved TOP/s is a small fraction of the 100 TOP/s peak
        assert!(r.achieved_ops < 15e12, "{}", r.achieved_ops);
    }

    #[test]
    fn greedy_allocator_respects_capacity() {
        let m = resnet50(1);
        let dev = DeviceSpec::fig3(4.0, 1.0);
        let r = roofline_model(&m, &dev);
        let used: f64 = m
            .layers
            .iter()
            .zip(&r.placements)
            .map(|(l, p)| {
                let mut b = 0.0;
                if p.weights_onchip {
                    b += l.weight_elems as f64 * dev.weight_bytes_per_elem;
                }
                if p.acts_onchip {
                    b += (l.act_in_elems + l.act_out_elems) as f64 * dev.act_bytes_per_elem;
                }
                b
            })
            .sum();
        assert!(used <= dev.onchip_capacity + 1.0, "used {used}");
        assert!(used > 0.0);
    }

    #[test]
    fn models_with_everything_onchip_hit_compute_roof() {
        // ResNet-50 int8 is 25 MB of weights; at 60 MB capacity and
        // 10 TB/s it should be compute bound near 100 TOP/s.
        let m = resnet50(1);
        let dev = DeviceSpec::fig3(60.0, 10.0);
        let r = roofline_model(&m, &dev);
        assert!(r.achieved_ops > 50e12, "{}", r.achieved_ops);
    }
}
