//! Fig-5 survey: the (M, N, K) GEMM shapes that actually occur across
//! the zoo, bucketed by op class (FC triangles, group/depth-wise conv
//! crosses, other convs circles). The paper's point: data-center GEMMs
//! are tall-and-skinny, not square — BLAS3 degrades toward BLAS2.

use crate::models::{GemmShape, ModelDesc, OpClass};

/// One scatter point of Fig 5.
#[derive(Debug, Clone)]
pub struct ShapePoint {
    pub model: String,
    pub layer: String,
    pub class: OpClass,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub groups: u64,
}

impl ShapePoint {
    /// Arithmetic intensity of the GEMM: 2MNK / (MK + KN + MN).
    pub fn intensity(&self) -> f64 {
        let (m, n, k) = (self.m as f64, self.n as f64, self.k as f64);
        2.0 * m * n * k / (m * k + k * n + m * n)
    }

    /// Tall-skinny measure: max dim / min dim.
    pub fn aspect(&self) -> f64 {
        let dims = [self.m, self.n, self.k];
        let max = *dims.iter().max().unwrap() as f64;
        let min = *dims.iter().min().unwrap() as f64;
        max / min.max(1.0)
    }

    /// The paper's "narrow GEMM ~ BLAS2" criterion: output feature dim
    /// or batch/spatial dim small (< 32).
    pub fn is_matrix_vector_like(&self) -> bool {
        self.m < 32 || self.n < 32
    }
}

/// Collect every GEMM shape in a set of models.
pub fn shape_survey(models: &[ModelDesc]) -> Vec<ShapePoint> {
    let mut out = Vec::new();
    for m in models {
        for l in &m.layers {
            if let Some(GemmShape { m: gm, n, k, groups }) = l.gemm {
                out.push(ShapePoint {
                    model: m.name.clone(),
                    layer: l.name.clone(),
                    class: l.class,
                    m: gm,
                    n,
                    k,
                    groups,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{recsys, representative_zoo, RecsysScale};

    fn zoo_models() -> Vec<ModelDesc> {
        representative_zoo().into_iter().map(|e| e.desc).collect()
    }

    #[test]
    fn survey_is_nonempty_and_covers_classes() {
        let pts = shape_survey(&zoo_models());
        assert!(pts.len() > 100);
        for class in [OpClass::Fc, OpClass::Conv, OpClass::GroupConv, OpClass::DepthwiseConv] {
            assert!(pts.iter().any(|p| p.class == class), "{class:?} missing");
        }
    }

    #[test]
    fn fc_and_groupconv_shapes_are_narrow() {
        // the paper: FCs (small batch) and group/depth-wise convs (few
        // output channels per group) degrade toward matrix-vector
        let pts = shape_survey(&[recsys(RecsysScale::Production, 10)]);
        let fc: Vec<_> = pts.iter().filter(|p| p.class == OpClass::Fc).collect();
        assert!(!fc.is_empty());
        assert!(fc.iter().all(|p| p.is_matrix_vector_like()));

        let dw: Vec<_> = shape_survey(&zoo_models())
            .into_iter()
            .filter(|p| p.class == OpClass::DepthwiseConv)
            .collect();
        assert!(dw.iter().all(|p| p.n < 32 && p.k < 32));
    }

    #[test]
    fn most_zoo_shapes_are_not_square() {
        let pts = shape_survey(&zoo_models());
        let skinny = pts.iter().filter(|p| p.aspect() > 4.0).count();
        // the Fig-5 story: the bulk of shapes are far from square
        assert!(skinny * 2 > pts.len(), "{skinny}/{}", pts.len());
    }

    #[test]
    fn intensity_formula() {
        let p = ShapePoint {
            model: "m".into(),
            layer: "l".into(),
            class: OpClass::Fc,
            m: 10,
            n: 10,
            k: 10,
            groups: 1,
        };
        // 2*1000 / 300
        assert!((p.intensity() - 2000.0 / 300.0).abs() < 1e-12);
    }
}
