//! Activation calibration (§3.2.2 techniques 4 & 5): histogram
//! observers over calibration inputs, L2-optimal clip-range search, and
//! net-aware range narrowing from the consumer op.

use crate::util::stats::Histogram;

use super::qparams::QParams;

/// Running observer over activation values.
#[derive(Debug, Clone)]
pub struct Calibrator {
    pub min: f32,
    pub max: f32,
    hist: Option<Histogram>,
    bins: usize,
}

impl Default for Calibrator {
    fn default() -> Self {
        Self::new(2048)
    }
}

impl Calibrator {
    pub fn new(bins: usize) -> Calibrator {
        Calibrator { min: f32::INFINITY, max: f32::NEG_INFINITY, hist: None, bins }
    }

    /// Observe a batch of activation values.
    pub fn observe(&mut self, xs: &[f32]) {
        if xs.is_empty() {
            return;
        }
        let (mut lo, mut hi) = (self.min, self.max);
        for &x in xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        // (re)build the histogram when the range widens, re-binning the
        // accumulated counts at their bin centers so earlier batches
        // keep their weight in the L2 search
        if self.hist.is_none() || lo < self.min || hi > self.max {
            self.min = lo.min(self.min);
            self.max = hi.max(self.max);
            let span = (self.max - self.min).max(1e-12);
            let mut fresh = Histogram::new(
                self.min as f64 - 1e-9,
                self.min as f64 + span as f64 + 1e-9,
                self.bins,
            );
            if let Some(old) = &self.hist {
                for (i, &cnt) in old.counts.iter().enumerate() {
                    if cnt > 0 {
                        let c = old.bin_center(i);
                        let f = (c - fresh.lo) / (fresh.hi - fresh.lo);
                        let idx =
                            ((f * fresh.counts.len() as f64) as usize).min(fresh.counts.len() - 1);
                        fresh.counts[idx] += cnt;
                    }
                }
            }
            self.hist = Some(fresh);
        }
        let h = self.hist.as_mut().unwrap();
        for &x in xs {
            h.push(x as f64);
        }
    }

    /// min/max qparams (the naive baseline).
    pub fn minmax_qparams(&self, bits: u32) -> QParams {
        QParams::from_range(self.min, self.max, bits, false)
    }

    /// Technique 4: clip range minimizing the L2 quantization error over
    /// the observed distribution (outliers get clipped when the bulk
    /// mass dominates).
    pub fn l2_optimal_qparams(&self, bits: u32, n_grid: usize) -> QParams {
        let Some(h) = &self.hist else {
            return QParams::from_range(0.0, 1.0, bits, false);
        };
        let amax = self.min.abs().max(self.max.abs()).max(1e-12);
        let mut best = self.minmax_qparams(bits);
        let mut best_err = f64::INFINITY;
        for g in 1..=n_grid {
            let clip = amax * g as f32 / n_grid as f32;
            let lo = self.min.max(-clip);
            let hi = self.max.min(clip);
            if hi <= lo {
                continue;
            }
            let qp = QParams::from_range(lo, hi, bits, false);
            let mut err = 0f64;
            for (i, &cnt) in h.counts.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                let c = h.bin_center(i) as f32;
                let d = (qp.fake_quant(c) - c) as f64;
                err += cnt as f64 * d * d;
            }
            if err < best_err {
                best_err = err;
                best = qp;
            }
        }
        best
    }

    /// Technique 5: narrow the range using knowledge of the consumer op.
    pub fn net_aware(&self, consumer: &str) -> Calibrator {
        let mut out = self.clone();
        match consumer {
            "relu" => out.min = out.min.max(0.0),
            "sigmoid" | "tanh" => {
                out.min = out.min.max(-8.0);
                out.max = out.max.min(8.0);
            }
            _ => {}
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn tracks_minmax() {
        let mut c = Calibrator::default();
        c.observe(&[1.0, -2.0]);
        c.observe(&[0.5, 3.0]);
        assert_eq!(c.min, -2.0);
        assert_eq!(c.max, 3.0);
    }

    #[test]
    fn l2_narrows_range_under_extreme_outliers() {
        let mut rng = Pcg32::seeded(31);
        let mut c = Calibrator::default();
        // a large Gaussian bulk: the L2 criterion only clips when the
        // bulk's resolution gain outweighs the outliers' clip error
        let bulk: Vec<f32> = (0..3_000_000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        c.observe(&bulk);
        c.observe(&[100.0, 100.0]); // two extreme outliers
        let mm = c.minmax_qparams(8);
        let l2 = c.l2_optimal_qparams(8, 64);
        assert!(l2.scale < mm.scale * 0.5, "l2 {} mm {}", l2.scale, mm.scale);
    }

    #[test]
    fn l2_keeps_full_range_without_outliers() {
        let mut rng = Pcg32::seeded(32);
        let mut c = Calibrator::default();
        let xs: Vec<f32> = (0..100_000).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        c.observe(&xs);
        let mm = c.minmax_qparams(8);
        let l2 = c.l2_optimal_qparams(8, 64);
        // uniform distribution: clipping only hurts
        assert!(l2.scale > mm.scale * 0.8, "l2 {} mm {}", l2.scale, mm.scale);
    }

    #[test]
    fn net_aware_relu_narrowing() {
        let mut c = Calibrator::default();
        c.observe(&[-4.0, 3.0]);
        let n = c.net_aware("relu");
        assert_eq!(n.min, 0.0);
        assert!(n.minmax_qparams(8).scale < c.minmax_qparams(8).scale);
    }

    #[test]
    fn net_aware_sigmoid_clamps_to_8() {
        let mut c = Calibrator::default();
        c.observe(&[-50.0, 50.0]);
        let n = c.net_aware("sigmoid");
        assert_eq!((n.min, n.max), (-8.0, 8.0));
    }
}
