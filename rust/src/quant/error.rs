//! Per-layer quantization error profiling (§3.2.2 technique 3):
//! "systematically profile errors introduced by quantization per layer
//! and skip quantization when the error is too high."

/// Signal-to-quantization-noise ratio in dB.
pub fn sqnr_db(reference: &[f32], test: &[f32]) -> f64 {
    assert_eq!(reference.len(), test.len());
    let mut sig = 0f64;
    let mut noise = 0f64;
    for (&r, &t) in reference.iter().zip(test) {
        sig += (r as f64) * (r as f64);
        let d = (r - t) as f64;
        noise += d * d;
    }
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig.max(1e-30) / noise).log10()
}

/// Per-layer report + the selective-quantization decision.
#[derive(Debug, Clone)]
pub struct ErrorReport {
    pub layer: String,
    pub sqnr_db: f64,
    pub l2_rel: f64,
    pub quantize: bool,
}

/// Profile one layer's quantized output against its fp32 output.
pub fn profile_error(layer: &str, reference: &[f32], test: &[f32], threshold_db: f64) -> ErrorReport {
    let s = sqnr_db(reference, test);
    let (mut num, mut den) = (0f64, 0f64);
    for (&r, &t) in reference.iter().zip(test) {
        num += ((r - t) as f64).powi(2);
        den += (r as f64).powi(2);
    }
    ErrorReport {
        layer: layer.to_string(),
        sqnr_db: s,
        l2_rel: (num / den.max(1e-30)).sqrt(),
        quantize: s >= threshold_db,
    }
}

/// Selective quantization: layers sorted worst-first so a fallback
/// budget (e.g. "keep the 2 most sensitive layers fp32") peels from the
/// front.
pub fn rank_by_sensitivity(mut reports: Vec<ErrorReport>) -> Vec<ErrorReport> {
    reports.sort_by(|a, b| a.sqnr_db.partial_cmp(&b.sqnr_db).unwrap());
    reports
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_is_infinite() {
        let x = [1.0f32, -2.0, 3.0];
        assert_eq!(sqnr_db(&x, &x), f64::INFINITY);
    }

    #[test]
    fn known_sqnr() {
        // signal power 1, noise power 0.01 -> 20 dB
        let r = [1.0f32; 100];
        let t = [1.1f32; 100];
        let s = sqnr_db(&r, &t);
        assert!((s - 20.0).abs() < 0.1, "{s}");
    }

    #[test]
    fn decision_threshold() {
        let r: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let good: Vec<f32> = r.iter().map(|v| v + 1e-4).collect();
        let bad: Vec<f32> = r.iter().map(|v| v + 0.3).collect();
        assert!(profile_error("good", &r, &good, 20.0).quantize);
        assert!(!profile_error("bad", &r, &bad, 20.0).quantize);
    }

    #[test]
    fn ranking_is_worst_first() {
        let r: Vec<f32> = (0..50).map(|i| i as f32 * 0.1).collect();
        let mk = |eps: f32| -> Vec<f32> { r.iter().map(|v| v + eps).collect() };
        let reports = vec![
            profile_error("a", &r, &mk(0.001), 20.0),
            profile_error("b", &r, &mk(0.5), 20.0),
            profile_error("c", &r, &mk(0.01), 20.0),
        ];
        let ranked = rank_by_sensitivity(reports);
        assert_eq!(ranked[0].layer, "b");
        assert_eq!(ranked[2].layer, "a");
    }
}
