//! Quantization toolkit (§3.2.2): qparams selection, calibration
//! observers, the five accuracy techniques, and the per-layer error
//! profiler behind selective quantization.
//!
//! This mirrors `python/compile/quantize.py` (which bakes qparams into
//! the AOT artifacts); the Rust side powers the fleet error profiler,
//! the ablation benches and the CLI `quantize` report.

pub mod calibrate;
pub mod error;
pub mod qparams;

pub use calibrate::Calibrator;
pub use error::{profile_error, sqnr_db, ErrorReport};
pub use qparams::{QParams, QuantGranularity};
