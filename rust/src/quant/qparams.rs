//! Affine quantization parameters and per-tensor / per-channel /
//! per-group granularities (§3.2.2 technique 1).

/// Quantization granularity (finer granularity -> better accuracy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantGranularity {
    PerTensor,
    /// one scale per output feature / channel
    PerChannel,
    /// one scale per group of channels (group convolutions)
    PerGroup(usize),
}

/// scale/zero-point pair for an affine mapping q = round(x/scale) + zp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
    pub bits: u32,
}

impl QParams {
    pub fn qmin(&self) -> i32 {
        -(1 << (self.bits - 1))
    }

    pub fn qmax(&self) -> i32 {
        (1 << (self.bits - 1)) - 1
    }

    /// Choose qparams covering [lo, hi] (always includes 0 so that zero
    /// is exactly representable — required for zero padding semantics).
    pub fn from_range(lo: f32, hi: f32, bits: u32, symmetric: bool) -> QParams {
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let (qmin, qmax) = (-(1i64 << (bits - 1)) as f32, ((1i64 << (bits - 1)) - 1) as f32);
        if symmetric {
            let amax = lo.abs().max(hi.abs());
            let scale = if amax > 0.0 { amax / qmax } else { 1.0 };
            return QParams { scale, zero_point: 0, bits };
        }
        let mut scale = (hi - lo) / (qmax - qmin);
        if scale == 0.0 {
            scale = 1.0;
        }
        let zp = (qmin - lo / scale).round().clamp(qmin, qmax) as i32;
        QParams { scale, zero_point: zp, bits }
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> i32 {
        let q = (x / self.scale).round() as i32 + self.zero_point;
        q.clamp(self.qmin(), self.qmax())
    }

    #[inline]
    pub fn dequantize(&self, q: i32) -> f32 {
        (q - self.zero_point) as f32 * self.scale
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i8> {
        assert!(self.bits <= 8);
        xs.iter().map(|&x| self.quantize(x) as i8).collect()
    }

    /// [`Self::quantize_slice`] into a reusable buffer: clear + refill,
    /// so a warm buffer costs zero heap allocations (the serving hot
    /// path quantizes activations per batch).
    pub fn quantize_into(&self, xs: &[f32], out: &mut Vec<i8>) {
        assert!(self.bits <= 8);
        out.clear();
        out.extend(xs.iter().map(|&x| self.quantize(x) as i8));
    }

    pub fn fake_quant(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }
}

/// Per-output-channel symmetric weight quantization of a `[N x K]`
/// matrix: returns (q, per-channel scales).
pub fn quantize_per_channel(w: &[f32], n: usize, k: usize, bits: u32) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), n * k);
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let mut q = vec![0i8; n * k];
    let mut scales = vec![0f32; n];
    for j in 0..n {
        let row = &w[j * k..(j + 1) * k];
        let amax = row.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-12);
        let s = amax / qmax;
        scales[j] = s;
        for kk in 0..k {
            q[j * k + kk] = ((row[kk] / s).round().clamp(-qmax - 1.0, qmax)) as i8;
        }
    }
    (q, scales)
}

/// Per-tensor symmetric weight quantization.
pub fn quantize_per_tensor(w: &[f32], bits: u32) -> (Vec<i8>, f32) {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let amax = w.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-12);
    let s = amax / qmax;
    (w.iter().map(|&v| ((v / s).round().clamp(-qmax - 1.0, qmax)) as i8).collect(), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let qp = QParams::from_range(-3.0, 5.0, 8, false);
        let mut x = -3.0f32;
        while x <= 5.0 {
            let err = (qp.fake_quant(x) - x).abs();
            assert!(err <= qp.scale * 0.5001, "{x}: {err} vs {}", qp.scale);
            x += 0.01;
        }
    }

    #[test]
    fn symmetric_has_zero_zp() {
        let qp = QParams::from_range(-2.0, 1.0, 8, true);
        assert_eq!(qp.zero_point, 0);
        assert!((qp.scale - 2.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn zero_is_exactly_representable() {
        for (lo, hi) in [(0.5f32, 4.0), (-7.0, -0.1), (-1.0, 1.0)] {
            let qp = QParams::from_range(lo, hi, 8, false);
            assert_eq!(qp.fake_quant(0.0), 0.0, "({lo},{hi})");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let qp = QParams::from_range(-1.0, 1.0, 8, true);
        assert_eq!(qp.quantize(100.0), 127);
        assert_eq!(qp.quantize(-100.0), -128);
    }

    #[test]
    fn per_channel_beats_per_tensor_on_diverse_scales() {
        let mut rng = Pcg32::seeded(21);
        let (n, k) = (8, 64);
        let mut w = vec![0f32; n * k];
        for j in 0..n {
            let scale = 10f32.powi(j as i32 % 3 - 2); // 0.01..1
            for kk in 0..k {
                w[j * k + kk] = rng.normal_f32(0.0, scale);
            }
        }
        let (q_pc, s_pc) = quantize_per_channel(&w, n, k, 8);
        let (q_pt, s_pt) = quantize_per_tensor(&w, 8);
        let err = |deq: &dyn Fn(usize, usize) -> f32| -> f64 {
            let mut e = 0f64;
            for j in 0..n {
                for kk in 0..k {
                    let d = deq(j, kk) - w[j * k + kk];
                    e += (d * d) as f64;
                }
            }
            e
        };
        let e_pc = err(&|j, kk| q_pc[j * k + kk] as f32 * s_pc[j]);
        let e_pt = err(&|j, kk| q_pt[j * k + kk] as f32 * s_pt);
        assert!(e_pc < e_pt * 0.5, "pc {e_pc} pt {e_pt}");
    }

    #[test]
    fn lower_bits_larger_error() {
        let mut rng = Pcg32::seeded(22);
        let w: Vec<f32> = (0..512).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut last = 0.0f64;
        for bits in [8u32, 6, 4, 2] {
            let (q, s) = quantize_per_tensor(&w, bits);
            let e: f64 = w
                .iter()
                .zip(&q)
                .map(|(&x, &qv)| ((qv as f32 * s - x) as f64).powi(2))
                .sum();
            assert!(e >= last, "bits {bits}: {e} < {last}");
            last = e;
        }
    }
}
