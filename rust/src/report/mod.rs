//! Report renderers: print the paper's tables and figure series in a
//! uniform textual form, shared by the CLI and the benches.

use crate::fleet::TimeBreakdown;
use crate::perfmodel::CharacterizationRow;
use crate::util::bench::Table;

/// Human format for parameter counts.
pub fn fmt_count(n: u64) -> String {
    let nf = n as f64;
    if nf >= 1e9 {
        format!("{:.1}B", nf / 1e9)
    } else if nf >= 1e6 {
        format!("{:.1}M", nf / 1e6)
    } else if nf >= 1e3 {
        format!("{:.1}K", nf / 1e3)
    } else {
        format!("{n}")
    }
}

/// Table 1 renderer.
pub fn print_table1(rows: &[CharacterizationRow]) {
    let mut t = Table::new(&[
        "Model",
        "Batch",
        "Params",
        "MaxLiveActs",
        "Ops/weight (avg/min)",
        "Ops/elem (avg/min)",
        "Latency",
    ]);
    for r in rows {
        t.row(&[
            r.model.clone(),
            r.batch.to_string(),
            fmt_count(r.params),
            fmt_count(r.max_live_acts),
            format!("{:.0} / {:.0}", r.intensity_w_avg, r.intensity_w_min),
            format!("{:.0} / {:.0}", r.intensity_full_avg, r.intensity_full_min),
            format!("{:?}", r.latency),
        ]);
    }
    t.print();
}

/// Fig 4 renderer: per-bucket time shares plus a text bar.
pub fn print_breakdown(b: &TimeBreakdown) {
    let mut entries: Vec<_> = b.buckets.iter().collect();
    entries.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
    println!("operator time breakdown (total {:.1} s simulated):", b.total_us / 1e6);
    for (bucket, (us, share)) in entries {
        let bar = "#".repeat((share * 60.0).round() as usize);
        println!("  {bucket:<12} {:>5.1}%  {bar}  ({:.2} s)", share * 100.0, us / 1e6);
    }
}

/// Fig 3 renderer: capacity sweep curves per model.
pub fn print_roofline_curves(model: &str, c1: &[(f64, f64)], c10: &[(f64, f64)]) {
    println!("{model}:");
    println!("  {:<10} {:>14} {:>14}", "cap (MB)", "1 TB/s (TOP/s)", "10 TB/s (TOP/s)");
    for ((mb, a), (_, b)) in c1.iter().zip(c10) {
        println!("  {mb:<10} {a:>14.2} {b:>14.2}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_count_units() {
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(25_000_000), "25.0M");
        assert_eq!(fmt_count(12_000_000_000), "12.0B");
        assert_eq!(fmt_count(1_500), "1.5K");
    }
}
