//! The execution-backend contract: how the serving tier runs an AOT
//! artifact without knowing *what* runs it.
//!
//! An [`ExecBackend`] loads a manifest artifact into a
//! [`LoadedArtifact`] and executes it with host tensors. Two
//! implementations ship:
//!
//! - [`PjrtBackend`] (cargo feature `pjrt`, default-on): wraps the XLA
//!   [`super::engine::Engine`] — compiles HLO text, keeps weights
//!   device-resident.
//! - [`super::native::NativeBackend`]: a pure-Rust interpreter over the
//!   manifest's per-artifact op program, dispatching FCs to the
//!   [`crate::gemm`] packed-B kernels (fp32/fp16/i8acc32/i8acc16) and
//!   pooled lookups to [`crate::embedding`] — the FBGEMM path of §3.2
//!   brought into the serving tier.
//!
//! Backends are **not** `Send` (PJRT handles are raw pointers); what
//! crosses threads is a [`BackendSpec`], and each executor thread
//! constructs its own backend from it via [`make_backend`] (or
//! [`make_backend_with_sparse`] to share a dis-aggregated embedding
//! tier). This is the same one-process-per-accelerator shape as §4's
//! dis-aggregated tier.
//!
//! ```no_run
//! use dcinfer::runtime::{make_backend, BackendSpec, Manifest};
//!
//! let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
//! let backend = make_backend(&BackendSpec::default())?;
//! let model = backend.load(&manifest, "recsys_fp32_b1")?;
//! println!("{} loaded in {:.0} ms", model.meta().name, model.load_ms());
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::embedding::shard::EmbeddingShardService;

use super::manifest::{ArtifactMeta, Manifest};
use super::precision::Precision;
use super::tensor::HostTensor;

/// What a backend must do to serve artifacts.
pub trait ExecBackend {
    /// Short backend id: `"pjrt"` or `"native"`.
    fn name(&self) -> &'static str;

    /// Human-readable platform string (e.g. the PJRT platform name).
    fn platform(&self) -> String;

    /// The execution precision this backend instance runs at.
    fn precision(&self) -> Precision;

    /// Every precision this backend can be constructed with.
    fn supported_precisions(&self) -> Vec<Precision>;

    /// Load one artifact (compile / pack weights) for execution.
    fn load(&self, manifest: &Manifest, artifact: &str) -> Result<Box<dyn LoadedArtifact>>;

    /// `backend/precision` label used for metrics attribution.
    fn label(&self) -> String {
        format!("{}/{}", self.name(), self.precision())
    }
}

/// A loaded artifact ready to execute.
pub trait LoadedArtifact {
    fn meta(&self) -> &ArtifactMeta;

    /// Execute with per-request activations; outputs follow the
    /// manifest's output metas.
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;

    /// Load (compile/pack/calibrate) wall time, for registry metrics.
    fn load_ms(&self) -> f64;
}

/// Validate host inputs against an artifact's manifest contract —
/// shared by every backend so error messages are uniform.
pub fn check_inputs(meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != meta.inputs.len() {
        bail!("{}: expected {} inputs, got {}", meta.name, meta.inputs.len(), inputs.len());
    }
    for (i, (got, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
        if got.dtype != want.dtype {
            bail!("{} input {i} ({}): dtype {:?} != {:?}", meta.name, want.name, got.dtype, want.dtype);
        }
        if got.shape != want.shape {
            bail!("{} input {i} ({}): shape {:?} != {:?}", meta.name, want.name, got.shape, want.shape);
        }
    }
    Ok(())
}

/// A `Send + Clone` description of which backend an executor thread
/// should construct — the value that crosses the thread boundary in
/// place of the non-`Send` backend itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendSpec {
    /// The XLA/PJRT engine (fp32 artifacts as lowered).
    #[cfg(feature = "pjrt")]
    Pjrt,
    /// The pure-Rust FBGEMM-path interpreter at a chosen precision,
    /// with `threads` intra-op GEMM workers per FC/conv (1 = serial;
    /// 0 = all available cores). More executors at threads=1 maximizes
    /// throughput; fewer executors with threads>1 cuts per-batch
    /// latency — the §3.1 cores-per-op vs concurrency trade.
    Native { precision: Precision, threads: usize },
}

impl Default for BackendSpec {
    #[cfg(feature = "pjrt")]
    fn default() -> Self {
        BackendSpec::Pjrt
    }

    #[cfg(not(feature = "pjrt"))]
    fn default() -> Self {
        BackendSpec::native(Precision::Fp32)
    }
}

impl BackendSpec {
    /// Native backend at `precision`, serial GEMMs (the common form).
    pub fn native(precision: Precision) -> BackendSpec {
        BackendSpec::Native { precision, threads: 1 }
    }

    /// Native backend with `threads` intra-op GEMM workers per op
    /// (0 = all available cores).
    pub fn native_threaded(precision: Precision, threads: usize) -> BackendSpec {
        BackendSpec::Native { precision, threads }
    }

    /// Set the intra-op GEMM thread count (native backend only).
    pub fn with_threads(self, threads: usize) -> Result<BackendSpec> {
        match self {
            BackendSpec::Native { precision, .. } => {
                Ok(BackendSpec::Native { precision, threads })
            }
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt => {
                // 1 is the no-op default; 0 (all cores) and >=2 are
                // real requests that pjrt cannot honor
                if threads == 1 {
                    Ok(self)
                } else {
                    bail!("--threads applies to the native backend (pjrt threads are XLA's)")
                }
            }
        }
    }

    /// Whether this spec resolves to the native interpreter — the only
    /// backend that routes embedding lookups through a sparse tier.
    pub fn is_native(&self) -> bool {
        match self {
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt => false,
            BackendSpec::Native { .. } => true,
        }
    }

    /// `backend/precision` label (matches [`ExecBackend::label`]).
    pub fn label(&self) -> String {
        match self {
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt => format!("pjrt/{}", Precision::Fp32),
            BackendSpec::Native { precision, .. } => format!("native/{precision}"),
        }
    }

    /// Parse a CLI `--backend`/`--precision` pair.
    pub fn from_cli(backend: &str, precision: &str) -> Result<BackendSpec> {
        let precision =
            if precision.is_empty() { Precision::Fp32 } else { Precision::from_manifest(precision)? };
        match backend {
            "native" => Ok(BackendSpec::native(precision)),
            #[cfg(feature = "pjrt")]
            "pjrt" => {
                if precision != Precision::Fp32 {
                    bail!("pjrt backend executes artifacts as lowered (fp32 only)");
                }
                Ok(BackendSpec::Pjrt)
            }
            other => {
                #[cfg(feature = "pjrt")]
                let hint = "expected native or pjrt";
                #[cfg(not(feature = "pjrt"))]
                let hint = "expected native; pjrt is compiled out";
                bail!("unknown backend {other} ({hint})")
            }
        }
    }
}

/// Construct the backend a spec describes. Called on the executor
/// thread that will own the (non-`Send`) result.
pub fn make_backend(spec: &BackendSpec) -> Result<Box<dyn ExecBackend>> {
    make_backend_with_sparse(spec, None)
}

/// [`make_backend`], optionally attaching the shared sparse tier. The
/// native backend routes its `embed_pool` ops through the tier; the
/// PJRT backend executes HLO with tables baked in and ignores it.
pub fn make_backend_with_sparse(
    spec: &BackendSpec,
    sparse: Option<Arc<EmbeddingShardService>>,
) -> Result<Box<dyn ExecBackend>> {
    match spec {
        #[cfg(feature = "pjrt")]
        BackendSpec::Pjrt => {
            let _ = sparse;
            Ok(Box::new(PjrtBackend::cpu()?))
        }
        BackendSpec::Native { precision, threads } => Ok(Box::new(
            match sparse {
                Some(tier) => super::native::NativeBackend::with_sparse_tier(*precision, tier),
                None => super::native::NativeBackend::new(*precision),
            }
            .with_threads(*threads),
        )),
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (feature `pjrt`)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub use pjrt_backend::PjrtBackend;

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use std::rc::Rc;

    use anyhow::Result;

    use crate::runtime::engine::{Engine, LoadedModel};
    use crate::runtime::manifest::{ArtifactMeta, Manifest};
    use crate::runtime::precision::Precision;
    use crate::runtime::tensor::HostTensor;

    use super::{ExecBackend, LoadedArtifact};

    /// [`ExecBackend`] over the XLA PJRT [`Engine`]. Artifacts execute
    /// exactly as lowered (fp32 graphs stay fp32; the baked-int8
    /// artifacts run their baked kernels).
    pub struct PjrtBackend {
        engine: Rc<Engine>,
    }

    impl PjrtBackend {
        pub fn cpu() -> Result<PjrtBackend> {
            Ok(PjrtBackend { engine: Rc::new(Engine::cpu()?) })
        }
    }

    impl ExecBackend for PjrtBackend {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn platform(&self) -> String {
            self.engine.platform()
        }

        fn precision(&self) -> Precision {
            Precision::Fp32
        }

        fn supported_precisions(&self) -> Vec<Precision> {
            vec![Precision::Fp32]
        }

        fn load(&self, manifest: &Manifest, artifact: &str) -> Result<Box<dyn LoadedArtifact>> {
            let model = self.engine.load(manifest, artifact)?;
            Ok(Box::new(PjrtArtifact { engine: self.engine.clone(), model }))
        }
    }

    struct PjrtArtifact {
        engine: Rc<Engine>,
        model: LoadedModel,
    }

    impl LoadedArtifact for PjrtArtifact {
        fn meta(&self) -> &ArtifactMeta {
            &self.model.meta
        }

        fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            self.model.run(&self.engine, inputs)
        }

        fn load_ms(&self) -> f64 {
            self.model.load_ms
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::DType;
    use crate::runtime::TensorMeta;

    fn meta() -> ArtifactMeta {
        ArtifactMeta {
            name: "m".into(),
            hlo: "m.hlo.txt".into(),
            model: None,
            weights: None,
            weight_params: vec![],
            inputs: vec![TensorMeta { name: "x".into(), dtype: DType::F32, shape: vec![2, 3] }],
            outputs: vec![],
            batch: 2,
            precision: Precision::Fp32,
            program: crate::util::json::Json::Null,
        }
    }

    #[test]
    fn check_inputs_enforces_contract() {
        let m = meta();
        let ok = vec![HostTensor::from_f32(&[2, 3], &[0.0; 6])];
        assert!(check_inputs(&m, &ok).is_ok());
        assert!(check_inputs(&m, &[]).is_err(), "arity");
        let bad_shape = vec![HostTensor::from_f32(&[3, 2], &[0.0; 6])];
        assert!(check_inputs(&m, &bad_shape).is_err());
        let bad_dtype = vec![HostTensor::from_i32(&[2, 3], &[0; 6])];
        assert!(check_inputs(&m, &bad_dtype).is_err());
    }

    #[test]
    fn spec_labels() {
        let s = BackendSpec::native(Precision::I8Acc16);
        assert!(s.is_native());
        assert_eq!(s.label(), "native/i8acc16");
        assert_eq!(BackendSpec::from_cli("native", "fp16").unwrap().label(), "native/fp16");
        assert!(BackendSpec::from_cli("nope", "").is_err());
    }

    #[test]
    fn threads_knob_round_trips() {
        let s = BackendSpec::native(Precision::Fp32).with_threads(4).unwrap();
        assert_eq!(s, BackendSpec::native_threaded(Precision::Fp32, 4));
        // the label (metrics attribution) is independent of threads
        assert_eq!(s.label(), "native/fp32");
        // distinct thread counts are distinct pool keys
        assert_ne!(s, BackendSpec::native(Precision::Fp32));
    }

    #[test]
    #[cfg(feature = "pjrt")]
    fn pjrt_spec_is_fp32_only() {
        assert_eq!(BackendSpec::default(), BackendSpec::Pjrt);
        assert_eq!(BackendSpec::Pjrt.label(), "pjrt/fp32");
        assert!(!BackendSpec::Pjrt.is_native());
        assert!(BackendSpec::from_cli("pjrt", "i8acc32").is_err());
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn default_spec_is_native_without_pjrt() {
        assert_eq!(BackendSpec::default(), BackendSpec::native(Precision::Fp32));
    }
}
