//! Engine: owns a PJRT client, compiles HLO-text artifacts, keeps model
//! weights resident on device, and executes with per-request activations.
//!
//! Not `Send` (PJRT handles are raw pointers) — see [`super::executor`]
//! for the threaded wrapper the coordinator uses.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::{DType, HostTensor};
use super::weights::read_weights_file;

/// PJRT client wrapper.
pub struct Engine {
    client: xla::PjRtClient,
}

/// A compiled artifact with device-resident weights.
pub struct LoadedModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    /// compile + weight-upload time, for the registry's metrics
    pub load_ms: f64,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact and upload its weights.
    pub fn load(&self, manifest: &Manifest, name: &str) -> Result<LoadedModel> {
        let meta = manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let hlo_path = manifest.hlo_path(&meta);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;

        // Upload weights once; they stay device-resident across requests.
        let mut weight_bufs = Vec::new();
        if let Some(wpath) = manifest.weights_path(&meta) {
            let tensors = read_weights_file(&wpath)?;
            let by_name: HashMap<&str, &HostTensor> =
                tensors.iter().map(|t| (t.name.as_str(), &t.tensor)).collect();
            for wp in &meta.weight_params {
                let t = by_name
                    .get(wp.name.as_str())
                    .with_context(|| format!("weight {} missing from {}", wp.name, wpath.display()))?;
                if t.shape != wp.shape {
                    bail!("weight {} shape {:?} != manifest {:?}", wp.name, t.shape, wp.shape);
                }
                weight_bufs.push(self.upload(t)?);
            }
        } else if !meta.weight_params.is_empty() {
            bail!("artifact {name} declares weight params but no weights file");
        }
        Ok(LoadedModel { meta, exe, weight_bufs, load_ms: t0.elapsed().as_secs_f64() * 1e3 })
    }

    /// Host -> device transfer.
    ///
    /// Uses the *typed* `buffer_from_host_buffer` — the raw-bytes variant
    /// in xla 0.1.6 passes the `ElementType` discriminant where PJRT
    /// expects a `PrimitiveType` (off by one: F32 arrives as F16).
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let buf = match t.dtype {
            DType::F32 => {
                let v = t.as_f32()?;
                self.client.buffer_from_host_buffer(&v, &t.shape, None)?
            }
            DType::I32 => {
                let v = t.as_i32()?;
                self.client.buffer_from_host_buffer(&v, &t.shape, None)?
            }
            DType::I8 => {
                let v = t.as_i8()?;
                self.client.buffer_from_host_buffer(&v, &t.shape, None)?
            }
        };
        Ok(buf)
    }
}

impl LoadedModel {
    /// Validate inputs against the manifest contract.
    pub fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (got, want)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if got.dtype != want.dtype {
                bail!("{} input {i} ({}): dtype {:?} != {:?}", self.meta.name, want.name, got.dtype, want.dtype);
            }
            if got.shape != want.shape {
                bail!("{} input {i} ({}): shape {:?} != {:?}", self.meta.name, want.name, got.shape, want.shape);
            }
        }
        Ok(())
    }

    /// Execute with device-resident weights + per-request activations.
    pub fn run(&self, engine: &Engine, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        let uploaded: Vec<xla::PjRtBuffer> =
            inputs.iter().map(|t| engine.upload(t)).collect::<Result<Vec<_>>>()?;
        args.extend(uploaded.iter());

        let result = self.exe.execute_b(&args)?;
        // return_tuple=True at lowering time: a single tuple output
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (lit, meta) in parts.into_iter().zip(&self.meta.outputs) {
            out.push(literal_to_host(&lit, meta.dtype, &meta.shape)?);
        }
        Ok(out)
    }
}

fn literal_to_host(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<HostTensor> {
    let data = match dtype {
        DType::F32 => {
            let v: Vec<f32> = lit.to_vec()?;
            v.iter().flat_map(|x| x.to_le_bytes()).collect()
        }
        DType::I32 => {
            let v: Vec<i32> = lit.to_vec()?;
            v.iter().flat_map(|x| x.to_le_bytes()).collect()
        }
        DType::I8 => {
            let v: Vec<i8> = lit.to_vec()?;
            v.iter().map(|&x| x as u8).collect()
        }
    };
    Ok(HostTensor { dtype, shape: shape.to_vec(), data })
}
