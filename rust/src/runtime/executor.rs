//! Threaded executor: one OS thread per (virtual) device owning a
//! non-`Send` [`ExecBackend`]; the coordinator talks to it over
//! channels.
//!
//! This mirrors the disaggregated-tier shape of §4: each executor is an
//! inference device; [`ExecutorPool`] is the tier. The backend itself
//! is constructed *on* the executor thread from a `Send`
//! [`BackendSpec`], so no unsafe `Send` is needed; requests carry only
//! host tensors.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::embedding::shard::EmbeddingShardService;

use super::backend::{make_backend_with_sparse, BackendSpec, ExecBackend, LoadedArtifact};
use super::manifest::Manifest;
use super::tensor::HostTensor;

/// A unit of device work.
struct ExecRequest {
    model: String,
    inputs: Vec<HostTensor>,
    resp: Sender<Result<ExecResponse>>,
}

/// Result of one execution.
#[derive(Debug)]
pub struct ExecResponse {
    pub outputs: Vec<HostTensor>,
    /// device-side wall time (upload + execute + download)
    pub exec_us: f64,
    /// `backend/precision` label of the serving executor (metrics
    /// attribution, e.g. `"native/i8acc16"`)
    pub backend: String,
}

enum Msg {
    Exec(ExecRequest),
    Shutdown,
}

/// Handle to a single executor thread.
#[derive(Clone)]
pub struct Executor {
    tx: Sender<Msg>,
    pub id: usize,
    /// `backend/precision` label of the backend this executor runs.
    pub backend: String,
}

impl Executor {
    /// Spawn an executor thread that constructs the backend `spec`
    /// describes and loads `artifact_names` from the manifest directory
    /// before accepting work.
    pub fn spawn(
        id: usize,
        spec: BackendSpec,
        artifacts_dir: PathBuf,
        artifact_names: Vec<String>,
    ) -> Result<(Executor, JoinHandle<()>)> {
        Self::spawn_with_sparse(id, spec, artifacts_dir, artifact_names, None)
    }

    /// [`Executor::spawn`] with a shared sparse tier: native backends
    /// fetch pooled embedding lookups through it instead of holding
    /// per-executor table copies.
    pub fn spawn_with_sparse(
        id: usize,
        spec: BackendSpec,
        artifacts_dir: PathBuf,
        artifact_names: Vec<String>,
        sparse: Option<Arc<EmbeddingShardService>>,
    ) -> Result<(Executor, JoinHandle<()>)> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<String>>();
        let handle = std::thread::Builder::new()
            .name(format!("executor-{id}"))
            .spawn(move || {
                executor_main(rx, ready_tx, &spec, &artifacts_dir, &artifact_names, sparse)
            })
            .context("spawning executor thread")?;
        let backend = ready_rx
            .recv()
            .map_err(|_| anyhow!("executor {id} died during startup"))??;
        Ok((Executor { tx, id, backend }, handle))
    }

    /// Synchronous execute (blocks until the device thread responds).
    pub fn run(&self, model: &str, inputs: Vec<HostTensor>) -> Result<ExecResponse> {
        let (resp_tx, resp_rx) = channel();
        self.tx
            .send(Msg::Exec(ExecRequest { model: model.to_string(), inputs, resp: resp_tx }))
            .map_err(|_| anyhow!("executor {} is gone", self.id))?;
        resp_rx.recv().map_err(|_| anyhow!("executor {} dropped the request", self.id))?
    }

    /// Fire-and-collect-later execute.
    pub fn run_async(&self, model: &str, inputs: Vec<HostTensor>) -> Result<Receiver<Result<ExecResponse>>> {
        let (resp_tx, resp_rx) = channel();
        self.tx
            .send(Msg::Exec(ExecRequest { model: model.to_string(), inputs, resp: resp_tx }))
            .map_err(|_| anyhow!("executor {} is gone", self.id))?;
        Ok(resp_rx)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

fn executor_main(
    rx: Receiver<Msg>,
    ready: Sender<Result<String>>,
    spec: &BackendSpec,
    artifacts_dir: &std::path::Path,
    artifact_names: &[String],
    sparse: Option<Arc<EmbeddingShardService>>,
) {
    let setup = (|| -> Result<(Box<dyn ExecBackend>, HashMap<String, Box<dyn LoadedArtifact>>)> {
        let backend = make_backend_with_sparse(spec, sparse)?;
        let manifest = Manifest::load(artifacts_dir)?;
        let mut models: HashMap<String, Box<dyn LoadedArtifact>> = HashMap::new();
        for name in artifact_names {
            let model = backend.load(&manifest, name)?;
            // warm the artifact: the first execution pays one-time JIT
            // finalization / buffer allocation (PJRT) or page-in of the
            // packed panels (native) that would otherwise land in a
            // request's p99
            let zeros: Vec<HostTensor> = model
                .meta()
                .inputs
                .iter()
                .map(|t| HostTensor {
                    dtype: t.dtype,
                    shape: t.shape.clone(),
                    data: vec![0u8; t.byte_len()],
                })
                .collect();
            let _ = model.run(&zeros)?;
            models.insert(name.clone(), model);
        }
        Ok((backend, models))
    })();

    let (backend, models) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(v.0.label()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let label = backend.label();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Exec(req) => {
                let t0 = Instant::now();
                let result = match models.get(&req.model) {
                    None => Err(anyhow!("model {} not loaded on this executor", req.model)),
                    Some(m) => m.run(&req.inputs).map(|outputs| ExecResponse {
                        outputs,
                        exec_us: t0.elapsed().as_secs_f64() * 1e6,
                        backend: label.clone(),
                    }),
                };
                let _ = req.resp.send(result);
            }
        }
    }
}

/// Live executors plus the join handles of every thread the pool ever
/// spawned (retired executors' handles stay here until
/// [`ExecutorPool::shutdown`] joins them — their threads are still
/// draining queued batches when a shrink returns).
struct PoolInner {
    executors: Vec<Executor>,
    handles: Vec<JoinHandle<()>>,
    retired: Vec<JoinHandle<()>>,
}

/// A pool of executor threads (the inference tier), resizable while
/// serving: [`ExecutorPool::resize`] grows by spawning executors with
/// the same spec/artifacts, and shrinks by sending retiring executors
/// their shutdown message — which queues *behind* any batches already
/// dispatched to them, so in-flight work drains rather than drops.
pub struct ExecutorPool {
    inner: Mutex<PoolInner>,
    spec: BackendSpec,
    /// spawn ingredients, kept so resize can add executors later
    artifacts_dir: PathBuf,
    artifact_names: Vec<String>,
    sparse: Option<Arc<EmbeddingShardService>>,
    /// monotonic executor-id source: retired ids are never reused, so
    /// thread names and logs stay unambiguous across resizes
    next_id: AtomicUsize,
    /// lock-free round-robin cursor (this sits on the hot dispatch path)
    next: AtomicUsize,
}

impl ExecutorPool {
    /// Spawn `n` executors of the backend `spec` describes, each
    /// loading the same artifact set.
    pub fn new(
        n: usize,
        spec: BackendSpec,
        artifacts_dir: PathBuf,
        artifact_names: Vec<String>,
    ) -> Result<ExecutorPool> {
        Self::with_sparse(n, spec, artifacts_dir, artifact_names, None)
    }

    /// [`ExecutorPool::new`] with a shared sparse tier (see
    /// [`Executor::spawn_with_sparse`]). Every executor shares the one
    /// tier, so N executors hold one sharded copy of the embedding
    /// tables instead of N monolithic ones.
    pub fn with_sparse(
        n: usize,
        spec: BackendSpec,
        artifacts_dir: PathBuf,
        artifact_names: Vec<String>,
        sparse: Option<Arc<EmbeddingShardService>>,
    ) -> Result<ExecutorPool> {
        let mut executors = Vec::new();
        let mut handles = Vec::new();
        for id in 0..n {
            let (e, h) = Executor::spawn_with_sparse(
                id,
                spec,
                artifacts_dir.clone(),
                artifact_names.clone(),
                sparse.clone(),
            )?;
            executors.push(e);
            handles.push(h);
        }
        Ok(ExecutorPool {
            inner: Mutex::new(PoolInner { executors, handles, retired: Vec::new() }),
            spec,
            artifacts_dir,
            artifact_names,
            sparse,
            next_id: AtomicUsize::new(n),
            next: AtomicUsize::new(0),
        })
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().executors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The backend spec every executor in this pool runs.
    pub fn spec(&self) -> BackendSpec {
        self.spec
    }

    /// Round-robin executor selection.
    pub fn pick(&self) -> Executor {
        let n = self.next.fetch_add(1, Ordering::Relaxed);
        let inner = self.inner.lock().unwrap();
        inner.executors[n % inner.executors.len()].clone()
    }

    /// The executor a router slot resolves to. Slot indexes wrap, so a
    /// dispatch decision made just before a concurrent shrink still
    /// lands on a live executor instead of panicking.
    pub fn executor(&self, slot: usize) -> Executor {
        let inner = self.inner.lock().unwrap();
        inner.executors[slot % inner.executors.len()].clone()
    }

    /// Grow or shrink the live executor set to `target` (clamped to at
    /// least 1). Growth spawns and warms new executors one at a time
    /// *outside* the pool lock, so serving never stalls behind artifact
    /// loading; shrink pops executors off the tail and sends each its
    /// shutdown message — queued batches on a retiring executor drain
    /// first because the message sits behind them in its channel.
    /// Returns the live count.
    pub fn resize(&self, target: usize) -> Result<usize> {
        let target = target.max(1);
        loop {
            let cur = self.len();
            if cur < target {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let (e, h) = Executor::spawn_with_sparse(
                    id,
                    self.spec,
                    self.artifacts_dir.clone(),
                    self.artifact_names.clone(),
                    self.sparse.clone(),
                )?;
                let mut inner = self.inner.lock().unwrap();
                inner.executors.push(e);
                inner.handles.push(h);
            } else if cur > target {
                let mut inner = self.inner.lock().unwrap();
                if inner.executors.len() > target {
                    let e = inner.executors.pop().expect("len > target >= 1");
                    let h = inner.handles.pop().expect("handles track executors");
                    e.shutdown();
                    inner.retired.push(h);
                }
            } else {
                return Ok(cur);
            }
        }
    }

    pub fn shutdown(self) {
        let inner = self.inner.into_inner().unwrap();
        for e in &inner.executors {
            e.shutdown();
        }
        for h in inner.handles.into_iter().chain(inner.retired) {
            let _ = h.join();
        }
    }
}
