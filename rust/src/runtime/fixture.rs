//! Self-synthesized artifacts fixture: a tiny recsys-lite + cv-lite +
//! gru-lite manifest with native op programs and DCIW weights, written
//! from pure Rust — no Python/JAX, no `make artifacts`, no PJRT.
//!
//! The backend-parity tests and the perf benches (`ablation_alloc`,
//! `e2e_serving` when real artifacts are absent) share this fixture so
//! they exercise the same load path (`Manifest::load` ->
//! `NativeBackend::load`) as production artifacts.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::rng::Pcg32;

use super::tensor::HostTensor;
use super::weights::{write_weights_file, NamedTensor};

fn tensor(rng: &mut Pcg32, name: &str, shape: &[usize], std: f32) -> NamedTensor {
    let count: usize = shape.iter().product();
    let mut data = vec![0f32; count];
    rng.fill_normal(&mut data, 0.0, std);
    NamedTensor { name: name.to_string(), tensor: HostTensor::from_f32(shape, &data) }
}

const RECSYS_PROG: &str = r#"[
  {"op": "fc", "out": "bot0", "in": "dense", "w": "bot_w0", "b": "bot_b0", "act": "relu"},
  {"op": "fc", "out": "bot1", "in": "bot0", "w": "bot_w1", "b": "bot_b1", "act": "relu"},
  {"op": "embed_pool", "out": "p0", "indices": "indices", "table": "emb_0", "slice": 0},
  {"op": "embed_pool", "out": "p1", "indices": "indices", "table": "emb_1", "slice": 1},
  {"op": "concat", "out": "z", "in": ["p0", "p1", "bot1"]},
  {"op": "fc", "out": "top0", "in": "z", "w": "top_w0", "b": "top_b0", "act": "relu"},
  {"op": "fc", "out": "top1", "in": "top0", "w": "top_w1", "b": "top_b1", "act": "none"},
  {"op": "unary", "fn": "sigmoid", "out": "prob", "in": "top1"}
]"#;

// the trailing tanh gives the cv family a fusable fc->unary chain, so
// every fixture family exercises at least one folded epilogue
const CV_PROG: &str = r#"[
  {"op": "conv2d", "out": "c1", "in": "image", "w": "conv1", "b": "b1", "act": "relu", "stride": 2, "pad": [0, 1]},
  {"op": "conv2d", "out": "c2", "in": "c1", "w": "conv2", "b": "b2", "act": "relu", "stride": 2, "pad": [0, 1]},
  {"op": "flatten", "out": "f", "in": "c2"},
  {"op": "fc", "out": "raw", "in": "f", "w": "fc_w", "b": "fc_b", "act": "none"},
  {"op": "unary", "fn": "tanh", "out": "logits", "in": "raw"}
]"#;

// gru-lite decode step: h_new = tanh(Wx·x + Wh·h); logits = Wo·h_new —
// the seq2seq inner loop's shape (two state tensors in, vocab logits +
// new state out), small enough to stay fixture-fast
const GRU_PROG: &str = r#"[
  {"op": "fc", "out": "hx", "in": "x", "w": "gx_w", "b": "gx_b", "act": "none"},
  {"op": "fc", "out": "hh", "in": "h", "w": "gh_w", "act": "none"},
  {"op": "binary", "fn": "add", "out": "pre", "a": "hx", "b": "hh"},
  {"op": "unary", "fn": "tanh", "out": "h_new", "in": "pre"},
  {"op": "fc", "out": "logits", "in": "h_new", "w": "out_w", "b": "out_b", "act": "none"}
]"#;

/// Write the fixture into `dir`: recsys-lite (dense 8, 2 tables of
/// 64x8, pool 4; batch variants 1 and 4), cv-lite (1x8x8 -> 4
/// classes; batch variants 1 and 2) and gru-lite (hidden 8, vocab 16
/// decode step with EOS token 0; batch variants 1, 4 and 8 — the extra
/// b8 gives the sequence plane's continuous batcher a wider table),
/// with model configs the `RecSysService`/`CvService`/`NmtService`
/// constructors understand.
pub fn write_synthetic_artifacts(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating fixture dir {}", dir.display()))?;

    let mut rng = Pcg32::seeded(1234);
    let recsys = vec![
        tensor(&mut rng, "emb_0", &[64, 8], 0.5),
        tensor(&mut rng, "emb_1", &[64, 8], 0.5),
        tensor(&mut rng, "bot_w0", &[16, 8], 0.3),
        tensor(&mut rng, "bot_b0", &[16], 0.1),
        tensor(&mut rng, "bot_w1", &[8, 16], 0.3),
        tensor(&mut rng, "bot_b1", &[8], 0.1),
        tensor(&mut rng, "top_w0", &[16, 24], 0.2),
        tensor(&mut rng, "top_b0", &[16], 0.1),
        tensor(&mut rng, "top_w1", &[1, 16], 0.2),
        tensor(&mut rng, "top_b1", &[1], 0.1),
    ];
    write_weights_file(&dir.join("recsys.weights.bin"), &recsys)?;
    let cv = vec![
        tensor(&mut rng, "conv1", &[4, 1, 3, 3], 0.3),
        tensor(&mut rng, "b1", &[4], 0.1),
        tensor(&mut rng, "conv2", &[8, 4, 3, 3], 0.2),
        tensor(&mut rng, "b2", &[8], 0.1),
        tensor(&mut rng, "fc_w", &[4, 32], 0.2),
        tensor(&mut rng, "fc_b", &[4], 0.1),
    ];
    write_weights_file(&dir.join("cv.weights.bin"), &cv)?;
    let gru = vec![
        tensor(&mut rng, "gx_w", &[8, 8], 0.3),
        tensor(&mut rng, "gx_b", &[8], 0.1),
        tensor(&mut rng, "gh_w", &[8, 8], 0.3),
        tensor(&mut rng, "out_w", &[16, 8], 0.2),
        tensor(&mut rng, "out_b", &[16], 0.1),
    ];
    write_weights_file(&dir.join("gru.weights.bin"), &gru)?;

    let mut artifacts = Vec::new();
    for b in [1usize, 4] {
        artifacts.push(format!(
            r#""recsys_fp32_b{b}": {{
              "hlo": "recsys_b{b}.hlo.txt", "model": "recsys",
              "weights": "recsys.weights.bin", "weight_params": [],
              "precision": "fp32", "program": {RECSYS_PROG},
              "inputs": [
                {{"name": "dense", "dtype": "f32", "shape": [{b}, 8]}},
                {{"name": "indices", "dtype": "i32", "shape": [{b}, 2, 4]}}
              ],
              "outputs": [{{"name": "prob", "dtype": "f32", "shape": [{b}, 1]}}],
              "batch": {b}
            }}"#
        ));
    }
    for b in [1usize, 2] {
        artifacts.push(format!(
            r#""cv_tiny_b{b}": {{
              "hlo": "cv_b{b}.hlo.txt", "model": "cv",
              "weights": "cv.weights.bin", "weight_params": [],
              "precision": "fp32", "program": {CV_PROG},
              "inputs": [{{"name": "image", "dtype": "f32", "shape": [{b}, 1, 8, 8]}}],
              "outputs": [{{"name": "logits", "dtype": "f32", "shape": [{b}, 4]}}],
              "batch": {b}
            }}"#
        ));
    }
    for b in [1usize, 4, 8] {
        artifacts.push(format!(
            r#""gru_step_b{b}": {{
              "hlo": "gru_b{b}.hlo.txt", "model": "gru",
              "weights": "gru.weights.bin", "weight_params": [],
              "precision": "fp32", "program": {GRU_PROG},
              "inputs": [
                {{"name": "x", "dtype": "f32", "shape": [{b}, 8]}},
                {{"name": "h", "dtype": "f32", "shape": [{b}, 8]}}
              ],
              "outputs": [
                {{"name": "logits", "dtype": "f32", "shape": [{b}, 16]}},
                {{"name": "h_new", "dtype": "f32", "shape": [{b}, 8]}}
              ],
              "batch": {b}
            }}"#
        ));
    }
    let manifest = format!(
        r#"{{
          "version": 1,
          "models": {{
            "recsys": {{"dense_dim": 8, "emb_dim": 8, "n_tables": 2, "pool": 4, "rows_per_table": 64}},
            "cv": {{"in_hw": 8, "channels": 1, "classes": 4}},
            "gru": {{"hidden": 8, "vocab": 16, "eos": 0}}
          }},
          "artifacts": {{ {} }}
        }}"#,
        artifacts.join(",\n")
    );
    std::fs::write(dir.join("manifest.json"), manifest)
        .with_context(|| format!("writing manifest to {}", dir.display()))?;
    Ok(())
}

/// Write the fixture into a fresh process-scoped temp dir and return
/// its path (callers clean up with `remove_dir_all` when done).
pub fn synthetic_artifacts_dir(tag: &str) -> Result<PathBuf> {
    let dir = std::env::temp_dir().join(format!("dcinfer_fixture_{tag}_{}", std::process::id()));
    if dir.exists() {
        std::fs::remove_dir_all(&dir)
            .with_context(|| format!("clearing stale fixture dir {}", dir.display()))?;
    }
    write_synthetic_artifacts(&dir)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{ExecBackend, LoadedArtifact as _};
    use crate::runtime::{Manifest, NativeBackend, Precision};

    #[test]
    fn fixture_loads_and_runs_on_the_native_backend() {
        let dir = synthetic_artifacts_dir("selftest").unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let art = NativeBackend::new(Precision::Fp32).load(&manifest, "recsys_fp32_b1").unwrap();
        let mut rng = Pcg32::seeded(2);
        let mut dense = vec![0f32; 8];
        rng.fill_normal(&mut dense, 0.0, 1.0);
        let idx: Vec<i32> = (0..8).map(|_| rng.below(64) as i32).collect();
        let out = art
            .run(&[
                HostTensor::from_f32(&[1, 8], &dense),
                HostTensor::from_i32(&[1, 2, 4], &idx),
            ])
            .unwrap();
        let p = out[0].as_f32().unwrap()[0];
        assert!(p > 0.0 && p < 1.0, "prob {p}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gru_lite_decode_step_runs_and_matches_hand_math() {
        let dir = synthetic_artifacts_dir("selftest_gru").unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let art = NativeBackend::new(Precision::Fp32).load(&manifest, "gru_step_b1").unwrap();
        let mut rng = Pcg32::seeded(5);
        let mut x = vec![0f32; 8];
        let mut h = vec![0f32; 8];
        rng.fill_normal(&mut x, 0.0, 1.0);
        rng.fill_normal(&mut h, 0.0, 0.5);
        let out = art
            .run(&[HostTensor::from_f32(&[1, 8], &x), HostTensor::from_f32(&[1, 8], &h)])
            .unwrap();
        assert_eq!(out[0].shape, vec![1, 16], "vocab logits");
        assert_eq!(out[1].shape, vec![1, 8], "new decoder state");
        // the state output is tanh-bounded; the logits are not constant
        let h_new = out[1].as_f32().unwrap();
        assert!(h_new.iter().all(|v| v.abs() <= 1.0));
        let logits = out[0].as_f32().unwrap();
        assert!(logits.iter().any(|v| v.abs() > 1e-6));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
