//! Artifact manifest (artifacts/manifest.json) parsing.
//!
//! Per-artifact fields beyond the parameter contract:
//!
//! - `precision` (optional, default `"fp32"`): the numeric variant the
//!   artifact *contains* — e.g. `recsys_int8_b16` bakes int8 weights
//!   into its HLO. Parsed into [`Precision`]. This is distinct from a
//!   backend's *execution* precision: the native backend re-quantizes
//!   fp32 weight files to any [`Precision`] at load time, so one fp32
//!   artifact family serves all four paths.
//! - `program` (optional): the small op program
//!   (`fc`/`conv2d`/`embed_pool`/`concat`/`unary`/`binary`/`flatten`)
//!   the AOT compiler emits for [`super::native::NativeBackend`]. Kept
//!   as raw [`Json`]; the native backend parses and packs it. Artifacts
//!   without a program are PJRT-only.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::precision::Precision;
use super::tensor::DType;

/// Shape+dtype of one HLO parameter or output.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorMeta {
    fn from_json(j: &Json) -> Result<TensorMeta> {
        let name = j.get("name").as_str().unwrap_or("").to_string();
        let dtype = DType::from_manifest(j.get("dtype").as_str().context("dtype")?)?;
        let shape = j
            .get("shape")
            .as_arr()
            .context("shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorMeta { name, dtype, shape })
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elem_count() * self.dtype.size()
    }
}

/// One AOT artifact: an HLO module plus its parameter contract.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub hlo: String,
    pub model: Option<String>,
    pub weights: Option<String>,
    pub weight_params: Vec<TensorMeta>,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub batch: usize,
    /// Numeric variant the artifact contains (`fp32` when unspecified).
    pub precision: Precision,
    /// Native-backend op program (`Json::Null` when absent).
    pub program: Json,
}

impl ArtifactMeta {
    /// Whether the pure-Rust backend can execute this artifact.
    pub fn has_native_program(&self) -> bool {
        !self.program.is_null()
    }
}

/// The parsed manifest, rooted at the artifacts directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub models: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        if root.get("version").as_usize() != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut artifacts = BTreeMap::new();
        let arts = root.get("artifacts").as_obj().context("artifacts object")?;
        for (name, a) in arts {
            let meta = ArtifactMeta {
                name: name.clone(),
                hlo: a.get("hlo").as_str().context("hlo path")?.to_string(),
                model: a.get("model").as_str().map(|s| s.to_string()),
                weights: a.get("weights").as_str().map(|s| s.to_string()),
                weight_params: a
                    .get("weight_params")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorMeta::from_json)
                    .collect::<Result<Vec<_>>>()?,
                inputs: a
                    .get("inputs")
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .map(TensorMeta::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(TensorMeta::from_json)
                    .collect::<Result<Vec<_>>>()?,
                batch: a.get("batch").as_usize().unwrap_or(1),
                precision: match a.get("precision").as_str() {
                    Some(s) => Precision::from_manifest(s)
                        .with_context(|| format!("artifact {name}"))?,
                    None => Precision::Fp32,
                },
                program: a.get("program").clone(),
            };
            artifacts.insert(name.clone(), meta);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, models: root.get("models").clone() })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }

    pub fn hlo_path(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.hlo)
    }

    pub fn weights_path(&self, a: &ArtifactMeta) -> Option<PathBuf> {
        a.weights.as_ref().map(|w| self.dir.join(w))
    }

    /// Names of artifacts for a given model family, e.g. all of one
    /// model's batch variants.
    pub fn artifacts_for_model(&self, model: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.values().filter(|a| a.model.as_deref() == Some(model)).collect()
    }

    /// Batch variants of an artifact family (`<prefix>_b<N>` naming),
    /// as `(batch, artifact_name)` sorted ascending by batch size.
    pub fn variants_for_prefix(&self, prefix: &str) -> Vec<(usize, String)> {
        let mut v: Vec<(usize, String)> = self
            .artifacts
            .values()
            .filter(|a| a.name.starts_with(prefix))
            .map(|a| (a.batch, a.name.clone()))
            .collect();
        v.sort();
        v
    }

    /// Per-model config block from the manifest's `models` section.
    pub fn model_config(&self, model: &str) -> Result<&Json> {
        let cfg = self.models.get(model);
        if cfg.is_null() {
            bail!("model {model} not in manifest models section");
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {"toy": {"dense_dim": 32}},
      "artifacts": {
        "m_b2": {
          "hlo": "m_b2.hlo.txt", "model": "toy", "weights": "m.weights.bin",
          "weight_params": [{"name": "w", "dtype": "f32", "shape": [4, 4]}],
          "inputs": [{"name": "x", "dtype": "f32", "shape": [2, 4]},
                     {"name": "idx", "dtype": "i32", "shape": [2, 3]}],
          "outputs": [{"name": "y", "dtype": "f32", "shape": [2, 1]}],
          "batch": 2
        },
        "m_b8": {
          "hlo": "m_b8.hlo.txt", "model": "toy", "weights": "m.weights.bin",
          "weight_params": [{"name": "w", "dtype": "f32", "shape": [4, 4]}],
          "inputs": [{"name": "x", "dtype": "f32", "shape": [8, 4]},
                     {"name": "idx", "dtype": "i32", "shape": [8, 3]}],
          "outputs": [{"name": "y", "dtype": "f32", "shape": [8, 1]}],
          "batch": 8
        },
        "k": {
          "hlo": "k.hlo.txt", "model": null, "weights": null,
          "weight_params": [],
          "inputs": [{"name": "x", "dtype": "i8", "shape": [8]}],
          "outputs": [{"name": "y", "dtype": "f32", "shape": [8]}],
          "batch": 8
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let a = m.artifact("m_b2").unwrap();
        assert_eq!(a.batch, 2);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.weight_params[0].byte_len(), 64);
        assert_eq!(m.hlo_path(a), PathBuf::from("/tmp/a/m_b2.hlo.txt"));
        assert!(m.weights_path(m.artifact("k").unwrap()).is_none());
        assert_eq!(m.artifacts_for_model("toy").len(), 2);
    }

    #[test]
    fn prefix_variants_sorted_by_batch() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        let v = m.variants_for_prefix("m_b");
        assert_eq!(v, vec![(2, "m_b2".to_string()), (8, "m_b8".to_string())]);
        assert!(m.variants_for_prefix("absent").is_empty());
    }

    #[test]
    fn model_config_lookup() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert_eq!(m.model_config("toy").unwrap().get("dense_dim").as_usize(), Some(32));
        assert!(m.model_config("absent").is_err());
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        assert!(Manifest::parse(Path::new("."), r#"{"version": 2, "artifacts": {}}"#).is_err());
    }

    #[test]
    fn precision_defaults_to_fp32_and_parses_variants() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        let a = m.artifact("m_b2").unwrap();
        assert_eq!(a.precision, crate::runtime::Precision::Fp32);
        assert!(!a.has_native_program());

        let src = r#"{
          "version": 1, "models": {},
          "artifacts": {
            "q": {
              "hlo": "q.hlo.txt", "model": null, "weights": null,
              "weight_params": [], "precision": "int8",
              "program": [{"op": "fc", "out": "y", "in": "x", "w": "w"}],
              "inputs": [{"name": "x", "dtype": "f32", "shape": [1, 2]}],
              "outputs": [{"name": "y", "dtype": "f32", "shape": [1, 1]}],
              "batch": 1
            }
          }
        }"#;
        let m = Manifest::parse(Path::new("."), src).unwrap();
        let a = m.artifact("q").unwrap();
        assert_eq!(a.precision, crate::runtime::Precision::I8Acc32);
        assert!(a.has_native_program());
        assert_eq!(a.program.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn rejects_unknown_precision() {
        let src = r#"{
          "version": 1, "models": {},
          "artifacts": {
            "q": {
              "hlo": "q.hlo.txt", "model": null, "weights": null,
              "weight_params": [], "precision": "fp8",
              "inputs": [], "outputs": [], "batch": 1
            }
          }
        }"#;
        assert!(Manifest::parse(Path::new("."), src).is_err());
    }
}
