//! Serving runtime: load AOT-compiled artifacts and execute them on the
//! request path through a pluggable [`ExecBackend`].
//!
//! Two backends ship:
//!
//! - **PJRT** (cargo feature `pjrt`, default-on): Python/JAX runs once
//!   at build time (`make artifacts`); [`engine`] compiles the HLO-text
//!   artifacts and keeps weights device-resident. The flow mirrors
//!   /opt/xla-example/load_hlo:
//!
//!   ```text
//!   PjRtClient::cpu()
//!     -> HloModuleProto::from_text_file(artifacts/<name>.hlo.txt)
//!     -> XlaComputation::from_proto -> client.compile
//!     -> upload weights once (buffer_from_host_raw_bytes)
//!     -> per request: upload activations, execute_b, download tuple
//!   ```
//!
//!   HLO *text* is the interchange format — jax >= 0.5 emits protos
//!   with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//!   text parser reassigns ids.
//!
//! - **Native** ([`native`], always available): a pure-Rust interpreter
//!   over the manifest's per-artifact op program, dispatching FCs to the
//!   [`crate::gemm`] reduced-precision kernels and pooled lookups to
//!   [`crate::embedding`] — §3.2's FBGEMM path in the serving tier, at
//!   any [`Precision`]. `cargo build --no-default-features` yields a
//!   pure-Rust binary with only this backend. At load time the op
//!   program is lowered into a fused [`plan::CompiledPlan`] (epilogue
//!   folding + pre-resolved dispatch); the interpreter survives as the
//!   numerics oracle behind `DCINFER_EXEC=interpret`.
//!
//! Backends hold raw pointers (PJRT) and are not `Send`, so
//! [`executor`] wraps each one in a dedicated thread per (virtual)
//! device — constructed in-thread from a `Send` [`BackendSpec`] — and
//! the coordinator talks to it over channels, the same shape as one
//! executor process per accelerator in a disaggregated tier (§4).

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod executor;
pub mod fixture;
pub mod manifest;
pub mod native;
pub mod plan;
pub mod precision;
pub mod tensor;
pub mod weights;

pub use backend::{
    check_inputs, make_backend, make_backend_with_sparse, BackendSpec, ExecBackend, LoadedArtifact,
};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use engine::{Engine, LoadedModel};
pub use executor::{Executor, ExecutorPool};
pub use fixture::{synthetic_artifacts_dir, write_synthetic_artifacts};
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
pub use native::{build_native_artifact, FcLayer, NativeArtifact, NativeBackend};
pub use plan::{CompiledPlan, FusedChain, FusionReport, MAX_TAIL};
pub use precision::Precision;
pub use tensor::{DType, HostTensor};
pub use weights::{read_weights_file, write_weights_file, NamedTensor};
