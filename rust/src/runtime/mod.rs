//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! on the request path with weights resident on device.
//!
//! Python/JAX runs once at build time (`make artifacts`); this module is
//! the only place the serving tier touches XLA. The flow mirrors
//! /opt/xla-example/load_hlo:
//!
//! ```text
//! PjRtClient::cpu()
//!   -> HloModuleProto::from_text_file(artifacts/<name>.hlo.txt)
//!   -> XlaComputation::from_proto -> client.compile
//!   -> upload weights once (buffer_from_host_raw_bytes)
//!   -> per request: upload activations, execute_b, download tuple
//! ```
//!
//! HLO *text* is the interchange format — jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.
//!
//! PJRT objects hold raw pointers and are not `Send`, so [`executor`]
//! wraps the engine in a dedicated thread per (virtual) device and the
//! coordinator talks to it over channels — the same shape as one
//! executor process per accelerator in a disaggregated tier (§4).

pub mod engine;
pub mod executor;
pub mod manifest;
pub mod tensor;
pub mod weights;

pub use engine::{Engine, LoadedModel};
pub use executor::{Executor, ExecutorPool};
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
pub use tensor::{DType, HostTensor};
pub use weights::read_weights_file;
