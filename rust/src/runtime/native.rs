//! Pure-Rust execution backend: interprets the small per-artifact op
//! program the AOT compiler emits into the manifest (`"program"` field),
//! dispatching FC/conv layers to the [`crate::gemm`] packed-B kernels
//! with the fused [`OutputPipeline`] and pooled sparse lookups to
//! [`crate::embedding`] — §3.2's FBGEMM path brought into the serving
//! tier, at any of the four [`Precision`] variants.
//!
//! The op set covers the serving families (FC/MLP chains, embedding
//! pooling, im2col conv, elementwise/concat glue):
//!
//! ```text
//! fc         out = act(in @ W^T + b)       gemm::{fp32,fp16,i8acc32,i8acc16}
//! conv2d     im2col + fc on patches        same kernels
//! embed_pool SparseLengthsSum per table    embedding::{table,quantized}
//! concat / flatten / unary / binary        elementwise glue
//! ```
//!
//! At int8 precisions, weights are re-quantized per-channel at load time
//! ([`crate::quant::qparams`]) and activation qparams come from a
//! calibration pass over synthetic inputs run through the fp32 program
//! ([`crate::quant::calibrate`], §3.2.2 techniques 1 & 4); embedding
//! tables switch to the row-wise-quantized
//! [`crate::embedding::QuantizedTable`].

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::embedding::shard::{EmbeddingShardService, ShardPlan};
use crate::embedding::{EmbeddingTable, LookupBatch, QuantizedTable};
use crate::gemm::{
    fp16::gemm_f16, fp32::gemm_f32, i8acc16::gemm_i8_acc16, i8acc32::gemm_i8_acc32,
    OutputPipeline, PackedBF16, PackedBF32, PackedBI8, PackedBI8Acc16,
};
use crate::quant::qparams::quantize_per_channel;
use crate::quant::{Calibrator, QParams};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::backend::{check_inputs, ExecBackend, LoadedArtifact};
use super::manifest::{ArtifactMeta, Manifest};
use super::precision::Precision;
use super::tensor::{DType, HostTensor};
use super::weights::{read_weights_file, NamedTensor};

/// How many synthetic batches the int8 calibration pass observes.
const CALIBRATION_BATCHES: usize = 2;
/// Grid resolution of the L2-optimal clip search (§3.2.2 technique 4).
const CALIBRATION_GRID: usize = 32;

// ---------------------------------------------------------------------------
// FcLayer: the packed-B kernel dispatch the whole backend (and the
// benches) route GEMMs through
// ---------------------------------------------------------------------------

/// One packed fully-connected layer at a fixed precision: weight
/// packing, activation quantization and the fused output pipeline in a
/// single dispatchable unit. This is the layer the interpreter executes
/// and the kernel benches drive, so both exercise the same path.
pub struct FcLayer {
    pub n: usize,
    pub k: usize,
    precision: Precision,
    pipe: OutputPipeline,
    kernel: FcKernel,
}

enum FcKernel {
    F32(PackedBF32),
    F16(PackedBF16),
    I8 { packed: PackedBI8, x_qp: QParams },
    I8Acc16 { packed: PackedBI8Acc16, x_qp: QParams },
}

impl FcLayer {
    /// Pack fp32 weights `w` (`[n x k]`, Caffe2 FC convention) for
    /// execution at `precision`. `x_qp` is the calibrated activation
    /// quantization (ignored by the fp paths). `relu` is fused into the
    /// output pipeline.
    pub fn from_f32(
        precision: Precision,
        w: &[f32],
        n: usize,
        k: usize,
        bias: Option<&[f32]>,
        relu: bool,
        x_qp: QParams,
    ) -> FcLayer {
        assert_eq!(w.len(), n * k);
        let bias_v = bias.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; n]);
        if let Some(b) = bias {
            assert_eq!(b.len(), n);
        }
        let (pipe, kernel) = match precision {
            Precision::Fp32 => {
                let mut pipe = OutputPipeline::identity(n, relu);
                pipe.bias = bias_v;
                (pipe, FcKernel::F32(PackedBF32::pack(w, n, k)))
            }
            Precision::Fp16 => {
                let mut pipe = OutputPipeline::identity(n, relu);
                pipe.bias = bias_v;
                (pipe, FcKernel::F16(PackedBF16::pack(w, n, k)))
            }
            Precision::I8Acc32 => {
                let (wq, wscale) = quantize_per_channel(w, n, k, 8);
                let packed = PackedBI8::pack(&wq, n, k);
                let pipe = OutputPipeline {
                    x_zp: x_qp.zero_point,
                    scale: wscale.iter().map(|s| s * x_qp.scale).collect(),
                    b_rowsum: packed.rowsum.clone(),
                    bias: bias_v,
                    relu,
                };
                (pipe, FcKernel::I8 { packed, x_qp })
            }
            Precision::I8Acc16 => {
                let (wq, wscale) = quantize_per_channel(w, n, k, 8);
                let packed = PackedBI8Acc16::pack(&wq, n, k);
                let pipe = OutputPipeline {
                    x_zp: x_qp.zero_point,
                    scale: wscale.iter().map(|s| s * x_qp.scale).collect(),
                    b_rowsum: packed.rowsum.clone(),
                    bias: bias_v,
                    relu,
                };
                (pipe, FcKernel::I8Acc16 { packed, x_qp })
            }
        };
        FcLayer { n, k, precision, pipe, kernel }
    }

    /// Build an acc16 layer from already-quantized int8 weights with a
    /// configurable main-path bit width — the outlier-threshold ablation
    /// knob (§3.2.1), exposed so the ablation bench drives the same
    /// dispatch path serving does.
    #[allow(clippy::too_many_arguments)]
    pub fn i8acc16_from_quantized(
        w_q: &[i8],
        n: usize,
        k: usize,
        main_bits: u32,
        x_qp: QParams,
        w_scale: f32,
        bias: Option<&[f32]>,
        relu: bool,
    ) -> FcLayer {
        assert_eq!(w_q.len(), n * k);
        let bias_v = bias.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; n]);
        let packed = PackedBI8Acc16::pack_bits(w_q, n, k, main_bits);
        let pipe = OutputPipeline {
            x_zp: x_qp.zero_point,
            scale: vec![w_scale * x_qp.scale; n],
            b_rowsum: packed.rowsum.clone(),
            bias: bias_v,
            relu,
        };
        FcLayer { n, k, precision: Precision::I8Acc16, pipe, kernel: FcKernel::I8Acc16 { packed, x_qp } }
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Outlier density of the acc16 sparse residual (None on other paths).
    pub fn outlier_density(&self) -> Option<f64> {
        match &self.kernel {
            FcKernel::I8Acc16 { packed, .. } => Some(packed.outliers.density()),
            _ => None,
        }
    }

    /// `out[M x N] = pipeline(x[M x K] * W^T)`; int8 paths quantize the
    /// fp32 activations with the layer's calibrated qparams first.
    pub fn forward(&self, x: &[f32], m: usize, out: &mut [f32]) {
        assert_eq!(x.len(), m * self.k);
        assert_eq!(out.len(), m * self.n);
        match &self.kernel {
            FcKernel::F32(p) => gemm_f32(x, m, p, &self.pipe, out),
            FcKernel::F16(p) => gemm_f16(x, m, p, &self.pipe, out),
            FcKernel::I8 { packed, x_qp } => {
                let xq = x_qp.quantize_slice(x);
                gemm_i8_acc32(&xq, m, packed, &self.pipe, out);
            }
            FcKernel::I8Acc16 { packed, x_qp } => {
                let xq = x_qp.quantize_slice(x);
                gemm_i8_acc16(&xq, m, packed, &self.pipe, out);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Program spec (parsed JSON) and compiled form
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Activation {
    Identity,
    Relu,
    Sigmoid,
    Tanh,
}

impl Activation {
    fn parse(s: &str) -> Result<Activation> {
        Ok(match s {
            "none" => Activation::Identity,
            "relu" => Activation::Relu,
            "sigmoid" => Activation::Sigmoid,
            "tanh" => Activation::Tanh,
            other => bail!("unknown activation {other}"),
        })
    }

    fn relu(self) -> bool {
        self == Activation::Relu
    }

    fn post(self) -> Option<UnaryFn> {
        match self {
            Activation::Sigmoid => Some(UnaryFn::Sigmoid),
            Activation::Tanh => Some(UnaryFn::Tanh),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum UnaryFn {
    Relu,
    Sigmoid,
    Tanh,
    OneMinus,
}

impl UnaryFn {
    fn parse(s: &str) -> Result<UnaryFn> {
        Ok(match s {
            "relu" => UnaryFn::Relu,
            "sigmoid" => UnaryFn::Sigmoid,
            "tanh" => UnaryFn::Tanh,
            "one_minus" => UnaryFn::OneMinus,
            other => bail!("unknown unary fn {other}"),
        })
    }

    fn apply(self, xs: &mut [f32]) {
        match self {
            UnaryFn::Relu => xs.iter_mut().for_each(|v| *v = v.max(0.0)),
            UnaryFn::Sigmoid => xs.iter_mut().for_each(|v| *v = 1.0 / (1.0 + (-*v).exp())),
            UnaryFn::Tanh => xs.iter_mut().for_each(|v| *v = v.tanh()),
            UnaryFn::OneMinus => xs.iter_mut().for_each(|v| *v = 1.0 - *v),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BinaryFn {
    Add,
    Mul,
}

impl BinaryFn {
    fn parse(s: &str) -> Result<BinaryFn> {
        Ok(match s {
            "add" => BinaryFn::Add,
            "mul" => BinaryFn::Mul,
            other => bail!("unknown binary fn {other}"),
        })
    }
}

/// One parsed program op (the manifest's JSON form).
#[derive(Debug, Clone)]
enum OpSpec {
    Fc { out: String, input: String, w: String, b: Option<String>, act: Activation },
    Conv2d {
        out: String,
        input: String,
        w: String,
        b: Option<String>,
        act: Activation,
        stride: usize,
        pad: (usize, usize),
    },
    EmbedPool { out: String, indices: String, table: String, slice: Option<usize> },
    Concat { out: String, inputs: Vec<String> },
    Unary { out: String, input: String, f: UnaryFn },
    Binary { out: String, a: String, b: String, f: BinaryFn },
    Flatten { out: String, input: String },
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key).as_str().with_context(|| format!("program op missing field {key:?}"))?.to_string())
}

fn opt_str(j: &Json, key: &str) -> Option<String> {
    j.get(key).as_str().map(|s| s.to_string())
}

impl OpSpec {
    fn parse(j: &Json) -> Result<OpSpec> {
        let op = j.get("op").as_str().context("program op missing \"op\"")?;
        let out = req_str(j, "out")?;
        Ok(match op {
            "fc" => OpSpec::Fc {
                out,
                input: req_str(j, "in")?,
                w: req_str(j, "w")?,
                b: opt_str(j, "b"),
                act: Activation::parse(j.get("act").as_str().unwrap_or("none"))?,
            },
            "conv2d" => {
                let pad = j.get("pad").as_arr().context("conv2d pad")?;
                ensure!(pad.len() == 2, "conv2d pad must be [lo, hi]");
                OpSpec::Conv2d {
                    out,
                    input: req_str(j, "in")?,
                    w: req_str(j, "w")?,
                    b: opt_str(j, "b"),
                    act: Activation::parse(j.get("act").as_str().unwrap_or("none"))?,
                    stride: j.get("stride").as_usize().context("conv2d stride")?,
                    pad: (
                        pad[0].as_usize().context("pad lo")?,
                        pad[1].as_usize().context("pad hi")?,
                    ),
                }
            }
            "embed_pool" => OpSpec::EmbedPool {
                out,
                indices: req_str(j, "indices")?,
                table: req_str(j, "table")?,
                slice: j.get("slice").as_usize(),
            },
            "concat" => OpSpec::Concat {
                out,
                inputs: j
                    .get("in")
                    .as_arr()
                    .context("concat in")?
                    .iter()
                    .map(|v| v.as_str().context("concat input name").map(|s| s.to_string()))
                    .collect::<Result<Vec<_>>>()?,
            },
            "unary" => OpSpec::Unary {
                out,
                input: req_str(j, "in")?,
                f: UnaryFn::parse(j.get("fn").as_str().context("unary fn")?)?,
            },
            "binary" => OpSpec::Binary {
                out,
                a: req_str(j, "a")?,
                b: req_str(j, "b")?,
                f: BinaryFn::parse(j.get("fn").as_str().context("binary fn")?)?,
            },
            "flatten" => OpSpec::Flatten { out, input: req_str(j, "in")? },
            other => bail!("unknown program op {other:?}"),
        })
    }
}

fn parse_program(j: &Json) -> Result<Vec<OpSpec>> {
    let arr = j
        .as_arr()
        .context("artifact has no native op program (rebuild artifacts with the current aot.py)")?;
    ensure!(!arr.is_empty(), "empty native op program");
    arr.iter().map(OpSpec::parse).collect()
}

/// Embedding table at the backend's precision: local (per-executor
/// copy) or shared through the dis-aggregated sparse tier.
enum PoolTable {
    F32(EmbeddingTable),
    Q(QuantizedTable),
    Shared { tier: Arc<EmbeddingShardService>, id: usize, rows: usize, dim: usize },
}

impl PoolTable {
    fn dims(&self) -> (usize, usize) {
        match self {
            PoolTable::F32(t) => (t.rows, t.dim),
            PoolTable::Q(t) => (t.rows, t.dim),
            PoolTable::Shared { rows, dim, .. } => (*rows, *dim),
        }
    }

    fn pool(&self, batch: &LookupBatch, out: &mut [f32]) -> Result<()> {
        match self {
            PoolTable::F32(t) => {
                t.sparse_lengths_sum(batch, out);
                Ok(())
            }
            PoolTable::Q(t) => {
                t.sparse_lengths_sum(batch, out);
                Ok(())
            }
            PoolTable::Shared { tier, id, .. } => tier.lookup(*id, batch, out),
        }
    }
}

/// Compiled op: spec plus packed weights at the target precision.
enum CompiledOp {
    Fc { out: String, input: String, layer: FcLayer, post: Option<UnaryFn> },
    Conv2d {
        out: String,
        input: String,
        layer: FcLayer,
        post: Option<UnaryFn>,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: (usize, usize),
    },
    EmbedPool { out: String, indices: String, table: usize, slice: Option<usize> },
    Concat { out: String, inputs: Vec<String> },
    Unary { out: String, input: String, f: UnaryFn },
    Binary { out: String, a: String, b: String, f: BinaryFn },
    Flatten { out: String, input: String },
}

struct CompiledProgram {
    ops: Vec<CompiledOp>,
    tables: Vec<PoolTable>,
}

/// A named f32 buffer flowing between ops.
struct Reg {
    shape: Vec<usize>,
    data: Vec<f32>,
}

fn weight<'a>(
    weights: &'a HashMap<String, &HostTensor>,
    name: &str,
) -> Result<&'a HostTensor> {
    weights.get(name).copied().with_context(|| format!("weight {name} missing from weights file"))
}

impl CompiledProgram {
    /// Pack every layer of `spec` at `precision`. `act_qparams` maps op
    /// index -> calibrated activation qparams (required for int8).
    /// With `sparse` set, embedding tables are registered into (and
    /// fetched through) the shared sparse tier instead of being copied
    /// into this executor; `scope` namespaces their keys so same-named
    /// tables of different model families don't collide.
    fn build(
        spec: &[OpSpec],
        weights: &HashMap<String, &HostTensor>,
        precision: Precision,
        act_qparams: Option<&HashMap<usize, QParams>>,
        sparse: Option<&Arc<EmbeddingShardService>>,
        scope: &str,
    ) -> Result<CompiledProgram> {
        let int8 = matches!(precision, Precision::I8Acc32 | Precision::I8Acc16);
        let qp_for = |i: usize| -> QParams {
            act_qparams
                .and_then(|m| m.get(&i).copied())
                // pre-calibration fp32 builds never read this
                .unwrap_or_else(|| QParams::from_range(-1.0, 1.0, 8, false))
        };
        let mut ops = Vec::with_capacity(spec.len());
        let mut tables: Vec<PoolTable> = Vec::new();
        let mut table_idx: HashMap<String, usize> = HashMap::new();
        for (i, op) in spec.iter().enumerate() {
            if int8 {
                ensure!(
                    !matches!(op, OpSpec::Fc { .. } | OpSpec::Conv2d { .. })
                        || act_qparams.map(|m| m.contains_key(&i)).unwrap_or(false),
                    "op {i} has no calibrated activation qparams"
                );
            }
            ops.push(match op {
                OpSpec::Fc { out, input, w, b, act } => {
                    let wt = weight(weights, w)?;
                    ensure!(wt.shape.len() == 2, "fc weight {w} must be 2-D, got {:?}", wt.shape);
                    let (n, k) = (wt.shape[0], wt.shape[1]);
                    let wdata = wt.as_f32()?;
                    let bias = match b {
                        Some(bn) => Some(weight(weights, bn)?.as_f32()?),
                        None => None,
                    };
                    let layer = FcLayer::from_f32(
                        precision,
                        &wdata,
                        n,
                        k,
                        bias.as_deref(),
                        act.relu(),
                        qp_for(i),
                    );
                    CompiledOp::Fc { out: out.clone(), input: input.clone(), layer, post: act.post() }
                }
                OpSpec::Conv2d { out, input, w, b, act, stride, pad } => {
                    let wt = weight(weights, w)?;
                    ensure!(
                        wt.shape.len() == 4,
                        "conv2d weight {w} must be [co, ci, kh, kw], got {:?}",
                        wt.shape
                    );
                    let (co, kh, kw) = (wt.shape[0], wt.shape[2], wt.shape[3]);
                    let k = wt.shape[1] * kh * kw;
                    let wdata = wt.as_f32()?;
                    let bias = match b {
                        Some(bn) => Some(weight(weights, bn)?.as_f32()?),
                        None => None,
                    };
                    let layer = FcLayer::from_f32(
                        precision,
                        &wdata,
                        co,
                        k,
                        bias.as_deref(),
                        act.relu(),
                        qp_for(i),
                    );
                    CompiledOp::Conv2d {
                        out: out.clone(),
                        input: input.clone(),
                        layer,
                        post: act.post(),
                        kh,
                        kw,
                        stride: *stride,
                        pad: *pad,
                    }
                }
                OpSpec::EmbedPool { out, indices, table, slice } => {
                    let idx = match table_idx.get(table).copied() {
                        Some(i) => i,
                        None => {
                            let wt = weight(weights, table)?;
                            ensure!(
                                wt.shape.len() == 2,
                                "embedding table {table} must be 2-D, got {:?}",
                                wt.shape
                            );
                            let t = EmbeddingTable::new(wt.shape[0], wt.shape[1], wt.as_f32()?);
                            tables.push(match sparse {
                                Some(tier) => {
                                    let key = format!("{scope}/{table}");
                                    let id = tier.register_table(&key, &t, int8)?;
                                    PoolTable::Shared {
                                        tier: tier.clone(),
                                        id,
                                        rows: t.rows,
                                        dim: t.dim,
                                    }
                                }
                                None if int8 => PoolTable::Q(QuantizedTable::from_f32(&t)),
                                None => PoolTable::F32(t),
                            });
                            table_idx.insert(table.clone(), tables.len() - 1);
                            tables.len() - 1
                        }
                    };
                    CompiledOp::EmbedPool {
                        out: out.clone(),
                        indices: indices.clone(),
                        table: idx,
                        slice: *slice,
                    }
                }
                OpSpec::Concat { out, inputs } => {
                    CompiledOp::Concat { out: out.clone(), inputs: inputs.clone() }
                }
                OpSpec::Unary { out, input, f } => {
                    CompiledOp::Unary { out: out.clone(), input: input.clone(), f: *f }
                }
                OpSpec::Binary { out, a, b, f } => CompiledOp::Binary {
                    out: out.clone(),
                    a: a.clone(),
                    b: b.clone(),
                    f: *f,
                },
                OpSpec::Flatten { out, input } => {
                    CompiledOp::Flatten { out: out.clone(), input: input.clone() }
                }
            });
        }
        Ok(CompiledProgram { ops, tables })
    }

    /// Interpret the program. `observers` (calibration mode) record the
    /// fp32 input distribution of every fc/conv op by op index.
    fn execute(
        &self,
        meta: &ArtifactMeta,
        inputs: &[HostTensor],
        mut observers: Option<&mut HashMap<usize, Calibrator>>,
    ) -> Result<HashMap<String, Reg>> {
        check_inputs(meta, inputs)?;
        let mut regs: HashMap<String, Reg> = HashMap::new();
        let mut int_regs: HashMap<String, (Vec<usize>, Vec<i32>)> = HashMap::new();
        for (t, m) in inputs.iter().zip(&meta.inputs) {
            match t.dtype {
                DType::F32 => {
                    regs.insert(m.name.clone(), Reg { shape: t.shape.clone(), data: t.as_f32()? });
                }
                DType::I32 => {
                    int_regs.insert(m.name.clone(), (t.shape.clone(), t.as_i32()?));
                }
                DType::I8 => bail!("native backend: i8 inputs unsupported ({})", m.name),
            }
        }

        for (i, op) in self.ops.iter().enumerate() {
            match op {
                CompiledOp::Fc { out, input, layer, post } => {
                    let (m, mut data) = {
                        let x = reg(&regs, input)?;
                        ensure!(!x.shape.is_empty(), "fc input {input} is scalar");
                        let m = x.shape[0];
                        let k: usize = x.shape[1..].iter().product();
                        ensure!(
                            k == layer.k,
                            "fc {out}: input {input} has {k} features, weight wants {}",
                            layer.k
                        );
                        if let Some(obs) = observers.as_deref_mut() {
                            obs.entry(i).or_insert_with(Calibrator::default).observe(&x.data);
                        }
                        let mut o = vec![0f32; m * layer.n];
                        layer.forward(&x.data, m, &mut o);
                        (m, o)
                    };
                    if let Some(f) = post {
                        f.apply(&mut data);
                    }
                    regs.insert(out.clone(), Reg { shape: vec![m, layer.n], data });
                }
                CompiledOp::Conv2d { out, input, layer, post, kh, kw, stride, pad } => {
                    let mut r = conv2d(
                        &regs, input, out, layer, *kh, *kw, *stride, *pad, i,
                        observers.as_deref_mut(),
                    )?;
                    if let Some(f) = post {
                        f.apply(&mut r.data);
                    }
                    regs.insert(out.clone(), r);
                }
                CompiledOp::EmbedPool { out, indices, table, slice } => {
                    let (shape, idx) = int_regs
                        .get(indices)
                        .with_context(|| format!("embed_pool: no i32 input named {indices}"))?;
                    let (flat, pool, bags) = match slice {
                        Some(t) => {
                            ensure!(
                                shape.len() == 3 && *t < shape[1],
                                "embed_pool slice {t} out of {indices} shape {shape:?}"
                            );
                            let (b, nt, p) = (shape[0], shape[1], shape[2]);
                            let mut v = Vec::with_capacity(b * p);
                            for bi in 0..b {
                                let base = (bi * nt + t) * p;
                                v.extend_from_slice(&idx[base..base + p]);
                            }
                            (v, p, b)
                        }
                        None => {
                            ensure!(shape.len() == 2, "embed_pool: {indices} must be [B, pool]");
                            (idx.clone(), shape[1], shape[0])
                        }
                    };
                    let (rows, dim) = self.tables[*table].dims();
                    for &v in &flat {
                        ensure!(
                            v >= 0 && (v as usize) < rows,
                            "embedding index {v} out of range 0..{rows}"
                        );
                    }
                    let batch =
                        LookupBatch::fixed(flat.iter().map(|&v| v as u32).collect(), pool);
                    let mut data = vec![0f32; bags * dim];
                    self.tables[*table].pool(&batch, &mut data)?;
                    regs.insert(out.clone(), Reg { shape: vec![bags, dim], data });
                }
                CompiledOp::Concat { out, inputs } => {
                    let r = {
                        let parts: Vec<&Reg> =
                            inputs.iter().map(|n| reg(&regs, n)).collect::<Result<Vec<_>>>()?;
                        ensure!(!parts.is_empty(), "concat with no inputs");
                        let b = parts[0].shape[0];
                        for (p, n) in parts.iter().zip(inputs) {
                            ensure!(
                                p.shape.len() == 2 && p.shape[0] == b,
                                "concat input {n} shape {:?} (want [{b}, _])",
                                p.shape
                            );
                        }
                        let total: usize = parts.iter().map(|p| p.shape[1]).sum();
                        let mut data = vec![0f32; b * total];
                        for bi in 0..b {
                            let mut off = 0usize;
                            for p in &parts {
                                let d = p.shape[1];
                                data[bi * total + off..bi * total + off + d]
                                    .copy_from_slice(&p.data[bi * d..(bi + 1) * d]);
                                off += d;
                            }
                        }
                        Reg { shape: vec![b, total], data }
                    };
                    regs.insert(out.clone(), r);
                }
                CompiledOp::Unary { out, input, f } => {
                    let r = {
                        let x = reg(&regs, input)?;
                        let mut data = x.data.clone();
                        f.apply(&mut data);
                        Reg { shape: x.shape.clone(), data }
                    };
                    regs.insert(out.clone(), r);
                }
                CompiledOp::Binary { out, a, b, f } => {
                    let r = {
                        let ra = reg(&regs, a)?;
                        let rb = reg(&regs, b)?;
                        ensure!(
                            ra.shape == rb.shape,
                            "binary {out}: {a} {:?} vs {b} {:?}",
                            ra.shape,
                            rb.shape
                        );
                        let data = match f {
                            BinaryFn::Add => {
                                ra.data.iter().zip(&rb.data).map(|(x, y)| x + y).collect()
                            }
                            BinaryFn::Mul => {
                                ra.data.iter().zip(&rb.data).map(|(x, y)| x * y).collect()
                            }
                        };
                        Reg { shape: ra.shape.clone(), data }
                    };
                    regs.insert(out.clone(), r);
                }
                CompiledOp::Flatten { out, input } => {
                    let r = {
                        let x = reg(&regs, input)?;
                        ensure!(!x.shape.is_empty(), "flatten of scalar {input}");
                        let rest: usize = x.shape[1..].iter().product();
                        Reg { shape: vec![x.shape[0], rest], data: x.data.clone() }
                    };
                    regs.insert(out.clone(), r);
                }
            }
        }
        Ok(regs)
    }
}

fn reg<'a>(regs: &'a HashMap<String, Reg>, name: &str) -> Result<&'a Reg> {
    regs.get(name).with_context(|| format!("program references undefined tensor {name:?}"))
}

/// im2col + packed GEMM. SAME-style padding is explicit `(lo, hi)`,
/// applied to both spatial dims (square kernels).
#[allow(clippy::too_many_arguments)]
fn conv2d(
    regs: &HashMap<String, Reg>,
    input: &str,
    out_name: &str,
    layer: &FcLayer,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: (usize, usize),
    op_idx: usize,
    observers: Option<&mut HashMap<usize, Calibrator>>,
) -> Result<Reg> {
    let x = reg(regs, input)?;
    ensure!(x.shape.len() == 4, "conv2d {out_name}: input {input} must be [B,C,H,W]");
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    ensure!(
        layer.k == c * kh * kw,
        "conv2d {out_name}: weight K {} != C*kh*kw {}",
        layer.k,
        c * kh * kw
    );
    let (plo, phi) = pad;
    ensure!(h + plo + phi >= kh && w + plo + phi >= kw, "conv2d {out_name}: kernel exceeds input");
    let ho = (h + plo + phi - kh) / stride + 1;
    let wo = (w + plo + phi - kw) / stride + 1;
    if let Some(obs) = observers {
        obs.entry(op_idx).or_insert_with(Calibrator::default).observe(&x.data);
    }

    let rows = b * ho * wo;
    let mut col = vec![0f32; rows * layer.k];
    for bi in 0..b {
        for y in 0..ho {
            for xx in 0..wo {
                let row = ((bi * ho + y) * wo + xx) * layer.k;
                let mut off = 0usize;
                for ci in 0..c {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (y * stride + ky) as isize - plo as isize;
                            let ix = (xx * stride + kx) as isize - plo as isize;
                            if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                col[row + off] = x.data
                                    [((bi * c + ci) * h + iy as usize) * w + ix as usize];
                            }
                            off += 1;
                        }
                    }
                }
            }
        }
    }
    let n = layer.n;
    let mut gemm_out = vec![0f32; rows * n];
    layer.forward(&col, rows, &mut gemm_out);
    // [B*ho*wo, co] -> NCHW
    let mut data = vec![0f32; b * n * ho * wo];
    for bi in 0..b {
        for y in 0..ho {
            for xx in 0..wo {
                let src = ((bi * ho + y) * wo + xx) * n;
                for co in 0..n {
                    data[((bi * n + co) * ho + y) * wo + xx] = gemm_out[src + co];
                }
            }
        }
    }
    Ok(Reg { shape: vec![b, n, ho, wo], data })
}

// ---------------------------------------------------------------------------
// Calibration (§3.2.2 techniques 1 & 4)
// ---------------------------------------------------------------------------

/// Deterministic synthetic calibration inputs matching the artifact's
/// input metas; i32 inputs draw below the smallest table they feed.
fn synth_calibration_inputs(
    meta: &ArtifactMeta,
    index_bounds: &HashMap<String, usize>,
    seed: u64,
) -> Vec<HostTensor> {
    let mut rng = Pcg32::seeded(seed);
    meta.inputs
        .iter()
        .map(|im| match im.dtype {
            DType::I32 => {
                let hi = *index_bounds.get(&im.name).unwrap_or(&1);
                let vals: Vec<i32> =
                    (0..im.elem_count()).map(|_| rng.below(hi.max(1) as u32) as i32).collect();
                HostTensor::from_i32(&im.shape, &vals)
            }
            _ => {
                let mut vals = vec![0f32; im.elem_count()];
                rng.fill_normal(&mut vals, 0.0, 1.0);
                HostTensor::from_f32(&im.shape, &vals)
            }
        })
        .collect()
}

/// Observe every fc/conv input through the fp32 program and pick
/// L2-optimal activation qparams per layer.
fn calibrate(
    fp32: &CompiledProgram,
    meta: &ArtifactMeta,
    index_bounds: &HashMap<String, usize>,
) -> Result<HashMap<usize, QParams>> {
    let mut observers: HashMap<usize, Calibrator> = HashMap::new();
    for b in 0..CALIBRATION_BATCHES {
        let inputs = synth_calibration_inputs(meta, index_bounds, 0x5eed + b as u64);
        fp32.execute(meta, &inputs, Some(&mut observers))?;
    }
    Ok(observers
        .into_iter()
        .map(|(i, cal)| (i, cal.l2_optimal_qparams(8, CALIBRATION_GRID)))
        .collect())
}

// ---------------------------------------------------------------------------
// Backend + artifact
// ---------------------------------------------------------------------------

/// Pure-Rust [`ExecBackend`] over the manifest op programs.
///
/// With a sparse tier attached ([`NativeBackend::with_sparse_tier`]),
/// `embed_pool` ops fetch pooled sums through the shared
/// [`EmbeddingShardService`] (registering each table on first load)
/// instead of holding a per-executor copy of every table — the §4
/// dis-aggregation of the sparse half of the model.
pub struct NativeBackend {
    precision: Precision,
    sparse: Option<Arc<EmbeddingShardService>>,
}

impl NativeBackend {
    pub fn new(precision: Precision) -> NativeBackend {
        NativeBackend { precision, sparse: None }
    }

    /// A backend whose pooled embedding lookups go through the shared
    /// sparse tier (int8 precisions register row-quantized slices).
    pub fn with_sparse_tier(
        precision: Precision,
        tier: Arc<EmbeddingShardService>,
    ) -> NativeBackend {
        NativeBackend { precision, sparse: Some(tier) }
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        "native-cpu (fbgemm-rs)".to_string()
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn supported_precisions(&self) -> Vec<Precision> {
        Precision::all().to_vec()
    }

    fn load(&self, manifest: &Manifest, artifact: &str) -> Result<Box<dyn LoadedArtifact>> {
        let meta = manifest.artifact(artifact)?.clone();
        let wpath = manifest.weights_path(&meta);
        let named: Vec<NamedTensor> = match &wpath {
            Some(p) => read_weights_file(p)?,
            None => Vec::new(),
        };
        // Before any table enters the shared tier, hold the compiler's
        // per-table shard metadata to the actual table shapes: drift
        // between manifest and weights fails the load, not a lookup.
        if self.sparse.is_some() {
            if let Some(model) = &meta.model {
                validate_sparse_shard_meta(manifest, model, &named)
                    .with_context(|| format!("artifact {artifact}: sparse_shards metadata"))?;
            }
        }
        Ok(Box::new(build_artifact(meta, &named, self.precision, self.sparse.clone())?))
    }
}

/// Validate the manifest's optional per-table `sparse_shards` row-range
/// metadata (emitted by `python/compile/aot.py`) against the weights
/// file: every listed table that exists must have ranges tiling exactly
/// `0..rows` ([`ShardPlan::from_json`]). Absent metadata is fine —
/// older manifests predate it.
fn validate_sparse_shard_meta(
    manifest: &Manifest,
    model: &str,
    named: &[NamedTensor],
) -> Result<()> {
    let Ok(cfg) = manifest.model_config(model) else {
        return Ok(()); // kernel artifacts have no model config
    };
    let shards = cfg.get("sparse_shards");
    if shards.is_null() {
        return Ok(());
    }
    let tables = shards.get("tables").as_obj().context("sparse_shards.tables must be an object")?;
    for (tname, ranges) in tables {
        let Some(t) = named.iter().find(|n| &n.name == tname) else {
            continue; // int8 variants carry a weight subset
        };
        ensure!(
            t.tensor.shape.len() == 2,
            "sparse_shards lists {tname}, which is not a 2-D table"
        );
        ShardPlan::from_json(ranges, t.tensor.shape[0])
            .with_context(|| format!("table {tname}"))?;
    }
    Ok(())
}

/// Compile one artifact's program at `precision` (weights already in
/// memory). Split out of [`NativeBackend::load`] so tests can build
/// artifacts without a manifest directory.
///
/// Calibration is deterministic, so every executor in a pool derives
/// identical qparams; each still packs/calibrates independently (same
/// per-thread-construction shape as the PJRT engine). Acceptable as
/// one-time startup cost at today's pool sizes — share the compiled
/// program via `Arc` if load time ever dominates.
pub(crate) fn build_artifact(
    meta: ArtifactMeta,
    named: &[NamedTensor],
    precision: Precision,
    sparse: Option<Arc<EmbeddingShardService>>,
) -> Result<NativeArtifact> {
    let t0 = Instant::now();
    let spec = parse_program(&meta.program)
        .with_context(|| format!("artifact {}: native program", meta.name))?;
    let weights: HashMap<String, &HostTensor> =
        named.iter().map(|t| (t.name.clone(), &t.tensor)).collect();
    // table keys are scoped by the weights file: batch variants of one
    // family share tier tables, distinct families never collide
    let scope = meta.weights.clone().unwrap_or_else(|| meta.name.clone());

    // smallest table each i32 input feeds, for calibration index synthesis
    let mut index_bounds: HashMap<String, usize> = HashMap::new();
    for op in &spec {
        if let OpSpec::EmbedPool { indices, table, .. } = op {
            let rows = weight(&weights, table)?.shape[0];
            let e = index_bounds.entry(indices.clone()).or_insert(rows);
            *e = (*e).min(rows);
        }
    }

    let program = match precision {
        Precision::Fp32 | Precision::Fp16 => {
            CompiledProgram::build(&spec, &weights, precision, None, sparse.as_ref(), &scope)?
        }
        Precision::I8Acc32 | Precision::I8Acc16 => {
            // calibration runs on local fp32 tables: it must not pollute
            // the tier's cache or register throwaway fp32 copies
            let fp32 = CompiledProgram::build(&spec, &weights, Precision::Fp32, None, None, &scope)?;
            let qparams = calibrate(&fp32, &meta, &index_bounds)?;
            CompiledProgram::build(
                &spec,
                &weights,
                precision,
                Some(&qparams),
                sparse.as_ref(),
                &scope,
            )?
        }
    };
    Ok(NativeArtifact { meta, program, load_ms: t0.elapsed().as_secs_f64() * 1e3 })
}

/// A compiled-and-packed native artifact.
pub struct NativeArtifact {
    meta: ArtifactMeta,
    program: CompiledProgram,
    load_ms: f64,
}

impl LoadedArtifact for NativeArtifact {
    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let regs = self.program.execute(&self.meta, inputs, None)?;
        let mut outs = Vec::with_capacity(self.meta.outputs.len());
        for om in &self.meta.outputs {
            ensure!(om.dtype == DType::F32, "native backend: output {} must be f32", om.name);
            let r = regs
                .get(&om.name)
                .with_context(|| format!("program never produced output {:?}", om.name))?;
            ensure!(
                r.shape == om.shape,
                "output {}: program shape {:?} != manifest {:?}",
                om.name,
                r.shape,
                om.shape
            );
            outs.push(HostTensor::from_f32(&r.shape, &r.data));
        }
        Ok(outs)
    }

    fn load_ms(&self) -> f64 {
        self.load_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::sqnr_db;
    use crate::runtime::manifest::TensorMeta;

    fn named(name: &str, shape: &[usize], data: Vec<f32>) -> NamedTensor {
        NamedTensor { name: name.to_string(), tensor: HostTensor::from_f32(shape, &data) }
    }

    fn meta_with(
        inputs: Vec<TensorMeta>,
        outputs: Vec<TensorMeta>,
        batch: usize,
        program: &str,
    ) -> ArtifactMeta {
        ArtifactMeta {
            name: "t".into(),
            hlo: "t.hlo.txt".into(),
            model: None,
            weights: Some("t.weights.bin".into()),
            weight_params: vec![],
            inputs,
            outputs,
            batch,
            precision: Precision::Fp32,
            program: Json::parse(program).unwrap(),
        }
    }

    fn tm(name: &str, dtype: DType, shape: &[usize]) -> TensorMeta {
        TensorMeta { name: name.into(), dtype, shape: shape.to_vec() }
    }

    #[test]
    fn fc_chain_matches_hand_math() {
        // y = sigmoid(relu(x @ W0^T + b0) @ W1^T)
        let w0 = vec![1.0, 0.0, 0.0, -1.0]; // [2x2] identity-ish
        let b0 = vec![0.5, 0.5];
        let w1 = vec![1.0, 1.0]; // [1x2]
        let prog = r#"[
            {"op": "fc", "out": "h", "in": "x", "w": "w0", "b": "b0", "act": "relu"},
            {"op": "fc", "out": "l", "in": "h", "w": "w1", "act": "none"},
            {"op": "unary", "fn": "sigmoid", "out": "y", "in": "l"}
        ]"#;
        let meta = meta_with(
            vec![tm("x", DType::F32, &[1, 2])],
            vec![tm("y", DType::F32, &[1, 1])],
            1,
            prog,
        );
        let ws = vec![
            named("w0", &[2, 2], w0),
            named("b0", &[2], b0),
            named("w1", &[1, 2], w1),
        ];
        let art = build_artifact(meta, &ws, Precision::Fp32, None).unwrap();
        let out = art.run(&[HostTensor::from_f32(&[1, 2], &[2.0, 3.0])]).unwrap();
        // h = relu([2 + .5, -3 + .5]) = [2.5, 0]; l = 2.5; y = sigmoid(2.5)
        let want = 1.0 / (1.0 + (-2.5f32).exp());
        let got = out[0].as_f32().unwrap()[0];
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn gru_style_elementwise_ops() {
        // h_new = (1 - z) * h + z * hh with z, h, hh as inputs
        let prog = r#"[
            {"op": "unary", "fn": "one_minus", "out": "omz", "in": "z"},
            {"op": "binary", "fn": "mul", "out": "a", "a": "omz", "b": "h"},
            {"op": "binary", "fn": "mul", "out": "b2", "a": "z", "b": "hh"},
            {"op": "binary", "fn": "add", "out": "h_new", "a": "a", "b": "b2"}
        ]"#;
        let meta = meta_with(
            vec![
                tm("z", DType::F32, &[1, 2]),
                tm("h", DType::F32, &[1, 2]),
                tm("hh", DType::F32, &[1, 2]),
            ],
            vec![tm("h_new", DType::F32, &[1, 2])],
            1,
            prog,
        );
        let art = build_artifact(meta, &[], Precision::Fp32, None).unwrap();
        let out = art
            .run(&[
                HostTensor::from_f32(&[1, 2], &[0.25, 1.0]),
                HostTensor::from_f32(&[1, 2], &[4.0, 4.0]),
                HostTensor::from_f32(&[1, 2], &[8.0, 8.0]),
            ])
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), vec![5.0, 8.0]);
    }

    #[test]
    fn embed_pool_slices_and_sums() {
        // 2 tables of 4 rows x 2 dims; indices [B=1, T=2, P=2]
        let t0: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let t1: Vec<f32> = (0..8).map(|v| (10 + v) as f32).collect();
        let prog = r#"[
            {"op": "embed_pool", "out": "p0", "indices": "idx", "table": "e0", "slice": 0},
            {"op": "embed_pool", "out": "p1", "indices": "idx", "table": "e1", "slice": 1},
            {"op": "concat", "out": "z", "in": ["p0", "p1"]}
        ]"#;
        let meta = meta_with(
            vec![tm("idx", DType::I32, &[1, 2, 2])],
            vec![tm("z", DType::F32, &[1, 4])],
            1,
            prog,
        );
        let ws = vec![named("e0", &[4, 2], t0), named("e1", &[4, 2], t1)];
        let art = build_artifact(meta, &ws, Precision::Fp32, None).unwrap();
        // table 0 pools rows {0, 1} -> [0+2, 1+3]; table 1 rows {2, 3} -> [14+16, 15+17]
        let out = art.run(&[HostTensor::from_i32(&[1, 2, 2], &[0, 1, 2, 3])]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), vec![2.0, 4.0, 30.0, 32.0]);
    }

    #[test]
    fn embed_pool_rejects_out_of_range_index() {
        let prog = r#"[{"op": "embed_pool", "out": "p", "indices": "idx", "table": "e0"}]"#;
        let meta = meta_with(
            vec![tm("idx", DType::I32, &[1, 2])],
            vec![tm("p", DType::F32, &[1, 2])],
            1,
            prog,
        );
        let ws = vec![named("e0", &[4, 2], vec![0.0; 8])];
        let art = build_artifact(meta, &ws, Precision::Fp32, None).unwrap();
        assert!(art.run(&[HostTensor::from_i32(&[1, 2], &[0, 4])]).is_err());
        assert!(art.run(&[HostTensor::from_i32(&[1, 2], &[-1, 0])]).is_err());
    }

    #[test]
    fn conv2d_matches_naive_reference() {
        let mut rng = Pcg32::seeded(3);
        let (b, c, h, w, co, k, stride) = (2usize, 3usize, 6usize, 6usize, 4usize, 3usize, 2usize);
        // SAME for stride 2, k 3, h 6: ho=3, total pad = (3-1)*2+3-6 = 1 -> (0,1)
        let (plo, phi) = (0usize, 1usize);
        let ho = (h + plo + phi - k) / stride + 1;
        let x: Vec<f32> = (0..b * c * h * w).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let wt: Vec<f32> = (0..co * c * k * k).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let bias: Vec<f32> = (0..co).map(|i| i as f32 * 0.1).collect();

        let prog = format!(
            r#"[{{"op": "conv2d", "out": "y", "in": "x", "w": "cw", "b": "cb",
                 "act": "relu", "stride": {stride}, "pad": [{plo}, {phi}]}}]"#
        );
        let meta = meta_with(
            vec![tm("x", DType::F32, &[b, c, h, w])],
            vec![tm("y", DType::F32, &[b, co, ho, ho])],
            b,
            &prog,
        );
        let ws = vec![named("cw", &[co, c, k, k], wt.clone()), named("cb", &[co], bias.clone())];
        let art = build_artifact(meta, &ws, Precision::Fp32, None).unwrap();
        let got = art.run(&[HostTensor::from_f32(&[b, c, h, w], &x)]).unwrap()[0]
            .as_f32()
            .unwrap();

        // naive reference
        for bi in 0..b {
            for o in 0..co {
                for y in 0..ho {
                    for xx in 0..ho {
                        let mut acc = bias[o];
                        for ci in 0..c {
                            for ky in 0..k {
                                for kx in 0..k {
                                    let iy = (y * stride + ky) as isize - plo as isize;
                                    let ix = (xx * stride + kx) as isize - plo as isize;
                                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w
                                    {
                                        acc += x[((bi * c + ci) * h + iy as usize) * w
                                            + ix as usize]
                                            * wt[((o * c + ci) * k + ky) * k + kx];
                                    }
                                }
                            }
                        }
                        let want = acc.max(0.0);
                        let gotv = got[((bi * co + o) * ho + y) * ho + xx];
                        assert!((gotv - want).abs() < 1e-4, "{gotv} vs {want}");
                    }
                }
            }
        }
    }

    #[test]
    fn parse_rejects_unknown_ops() {
        assert!(parse_program(&Json::parse(r#"[{"op": "nope", "out": "x"}]"#).unwrap()).is_err());
        assert!(parse_program(&Json::parse("[]").unwrap()).is_err());
        assert!(parse_program(&Json::Null).is_err());
    }

    fn tiny_mlp_artifact(precision: Precision) -> (NativeArtifact, Vec<HostTensor>) {
        let mut rng = Pcg32::seeded(7);
        let (din, dh, dout) = (8usize, 16usize, 4usize);
        let w0: Vec<f32> = (0..dh * din).map(|_| rng.normal_f32(0.0, 0.4)).collect();
        let b0: Vec<f32> = (0..dh).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let w1: Vec<f32> = (0..dout * dh).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let prog = r#"[
            {"op": "fc", "out": "h", "in": "x", "w": "w0", "b": "b0", "act": "relu"},
            {"op": "fc", "out": "y", "in": "h", "w": "w1", "act": "none"}
        ]"#;
        let meta = meta_with(
            vec![tm("x", DType::F32, &[4, din])],
            vec![tm("y", DType::F32, &[4, dout])],
            4,
            prog,
        );
        let ws = vec![
            named("w0", &[dh, din], w0),
            named("b0", &[dh], b0),
            named("w1", &[dout, dh], w1),
        ];
        let art = build_artifact(meta, &ws, precision, None).unwrap();
        let mut x = vec![0f32; 4 * din];
        let mut rng = Pcg32::seeded(99);
        rng.fill_normal(&mut x, 0.0, 1.0);
        (art, vec![HostTensor::from_f32(&[4, din], &x)])
    }

    #[test]
    fn reduced_precisions_track_fp32_within_bounds() {
        let (ref_art, inputs) = tiny_mlp_artifact(Precision::Fp32);
        let reference = ref_art.run(&inputs).unwrap()[0].as_f32().unwrap();
        for p in [Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16] {
            let (art, _) = tiny_mlp_artifact(p);
            let got = art.run(&inputs).unwrap()[0].as_f32().unwrap();
            let db = sqnr_db(&reference, &got);
            assert!(db >= p.min_sqnr_db(), "{p}: sqnr {db:.1} dB < {}", p.min_sqnr_db());
        }
    }

    #[test]
    fn fc_layer_precisions_agree_on_random_gemm() {
        let mut rng = Pcg32::seeded(21);
        let (m, n, k) = (8usize, 32usize, 64usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let w: Vec<f32> = (0..n * k).map(|_| rng.normal_f32(0.0, 0.2)).collect();
        let (lo, hi) = a.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let x_qp = QParams::from_range(lo, hi, 8, false);
        let mut reference = vec![0f32; m * n];
        FcLayer::from_f32(Precision::Fp32, &w, n, k, None, false, x_qp)
            .forward(&a, m, &mut reference);
        for p in [Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16] {
            let layer = FcLayer::from_f32(p, &w, n, k, None, false, x_qp);
            assert_eq!(layer.precision(), p);
            let mut c = vec![0f32; m * n];
            layer.forward(&a, m, &mut c);
            let db = sqnr_db(&reference, &c);
            assert!(db >= p.min_sqnr_db(), "{p}: sqnr {db:.1} dB");
        }
    }

    #[test]
    fn acc16_ablation_constructor_gets_denser_outliers_at_fewer_bits() {
        let mut rng = Pcg32::seeded(31);
        let (n, k) = (32usize, 64usize);
        let wq: Vec<i8> =
            (0..n * k).map(|_| rng.normal_f32(0.0, 24.0).round().clamp(-127.0, 127.0) as i8).collect();
        let qp = QParams::from_range(-1.0, 1.0, 8, false);
        let d7 = FcLayer::i8acc16_from_quantized(&wq, n, k, 7, qp, 0.01, None, false)
            .outlier_density()
            .unwrap();
        let d4 = FcLayer::i8acc16_from_quantized(&wq, n, k, 4, qp, 0.01, None, false)
            .outlier_density()
            .unwrap();
        assert!(d4 > d7, "d4 {d4} d7 {d7}");
    }
}
