//! Pure-Rust execution backend: interprets the small per-artifact op
//! program the AOT compiler emits into the manifest (`"program"` field),
//! dispatching FC/conv layers to the [`crate::gemm`] packed-B kernels
//! with the fused [`OutputPipeline`] and pooled sparse lookups to
//! [`crate::embedding`] — §3.2's FBGEMM path brought into the serving
//! tier, at any of the four [`Precision`] variants.
//!
//! The op set covers the serving families (FC/MLP chains, embedding
//! pooling, im2col conv, elementwise/concat glue):
//!
//! ```text
//! fc         out = act(in @ W^T + b)       gemm::{fp32,fp16,i8acc32,i8acc16}
//! conv2d     im2col + fc on patches        same kernels
//! embed_pool SparseLengthsSum per table    embedding::{table,quantized}
//! concat / flatten / unary / binary        elementwise glue
//! ```
//!
//! **Execution arena.** Artifact input shapes are fixed, so every
//! intermediate shape is known at `build()` time. The compiler resolves
//! register names to dense slot indices, precomputes every buffer size
//! (including conv im2col scratch), turns `flatten` into a zero-cost
//! alias and applies `unary` in place when its input is dead — and each
//! loaded artifact keeps one reusable [`ExecArena`] of those buffers.
//! Steady-state execution performs **zero heap allocations**: inputs
//! are decoded into arena slots, ops run `take -> compute -> put back`
//! on preallocated buffers, and int8 activation quantization uses a
//! thread-local high-water scratch. (`ablation_alloc` measures this —
//! see [`NativeArtifact::execute_steady`] vs
//! [`NativeArtifact::execute_fresh`].)
//!
//! At int8 precisions, weights are re-quantized per-channel at load time
//! ([`crate::quant::qparams`]) and activation qparams come from a
//! calibration pass over synthetic inputs run through the fp32 program
//! ([`crate::quant::calibrate`], §3.2.2 techniques 1 & 4); embedding
//! tables switch to the row-wise-quantized
//! [`crate::embedding::QuantizedTable`].

use std::cell::RefCell;
use std::collections::HashMap;
use std::mem;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::embedding::shard::{EmbeddingShardService, ShardPlan};
use crate::embedding::{EmbeddingTable, LookupBatch, QuantizedTable};
use crate::gemm::{
    fp16::gemm_f16_ep, fp32::gemm_f32_ep, i8acc16::gemm_i8_acc16_ep, i8acc32::gemm_i8_acc32_ep,
    Epilogue, GemmCtx, OutputPipeline, PackedBF16, PackedBF32, PackedBI8, PackedBI8Acc16, TailOp,
};
use crate::quant::qparams::quantize_per_channel;
use crate::quant::{Calibrator, QParams};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::backend::{check_inputs, ExecBackend, LoadedArtifact};
use super::manifest::{ArtifactMeta, Manifest};
use super::plan::{CompiledPlan, FusionReport};
use super::precision::Precision;
use super::tensor::{DType, HostTensor};
use super::weights::{read_weights_file, NamedTensor};

/// How many synthetic batches the int8 calibration pass observes.
const CALIBRATION_BATCHES: usize = 2;
/// Grid resolution of the L2-optimal clip search (§3.2.2 technique 4).
const CALIBRATION_GRID: usize = 32;

// ---------------------------------------------------------------------------
// FcLayer: the packed-B kernel dispatch the whole backend (and the
// benches) route GEMMs through
// ---------------------------------------------------------------------------

thread_local! {
    /// Reused int8 activation-quantization buffer: after the first
    /// batch on a thread it sits at its high-water capacity, so the
    /// serving hot path quantizes without allocating.
    static QUANT_SCRATCH: RefCell<Vec<i8>> = const { RefCell::new(Vec::new()) };
}

/// One packed fully-connected layer at a fixed precision: weight
/// packing, activation quantization and the fused output pipeline in a
/// single dispatchable unit. This is the layer the interpreter executes
/// and the kernel benches drive, so both exercise the same path.
pub struct FcLayer {
    pub n: usize,
    pub k: usize,
    precision: Precision,
    pipe: OutputPipeline,
    kernel: FcKernel,
    ctx: GemmCtx,
}

enum FcKernel {
    F32(PackedBF32),
    F16(PackedBF16),
    I8 { packed: PackedBI8, x_qp: QParams },
    I8Acc16 { packed: PackedBI8Acc16, x_qp: QParams },
}

impl FcLayer {
    /// Pack fp32 weights `w` (`[n x k]`, Caffe2 FC convention) for
    /// execution at `precision`. `x_qp` is the calibrated activation
    /// quantization (ignored by the fp paths). `relu` is fused into the
    /// output pipeline.
    pub fn from_f32(
        precision: Precision,
        w: &[f32],
        n: usize,
        k: usize,
        bias: Option<&[f32]>,
        relu: bool,
        x_qp: QParams,
    ) -> FcLayer {
        assert_eq!(w.len(), n * k);
        let bias_v = bias.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; n]);
        if let Some(b) = bias {
            assert_eq!(b.len(), n);
        }
        let (pipe, kernel) = match precision {
            Precision::Fp32 => {
                let mut pipe = OutputPipeline::identity(n, relu);
                pipe.bias = bias_v;
                (pipe, FcKernel::F32(PackedBF32::pack(w, n, k)))
            }
            Precision::Fp16 => {
                let mut pipe = OutputPipeline::identity(n, relu);
                pipe.bias = bias_v;
                (pipe, FcKernel::F16(PackedBF16::pack(w, n, k)))
            }
            Precision::I8Acc32 => {
                let (wq, wscale) = quantize_per_channel(w, n, k, 8);
                let packed = PackedBI8::pack(&wq, n, k);
                let pipe = OutputPipeline {
                    x_zp: x_qp.zero_point,
                    scale: wscale.iter().map(|s| s * x_qp.scale).collect(),
                    b_rowsum: packed.rowsum.clone(),
                    bias: bias_v,
                    relu,
                };
                (pipe, FcKernel::I8 { packed, x_qp })
            }
            Precision::I8Acc16 => {
                let (wq, wscale) = quantize_per_channel(w, n, k, 8);
                let packed = PackedBI8Acc16::pack(&wq, n, k);
                let pipe = OutputPipeline {
                    x_zp: x_qp.zero_point,
                    scale: wscale.iter().map(|s| s * x_qp.scale).collect(),
                    b_rowsum: packed.rowsum.clone(),
                    bias: bias_v,
                    relu,
                };
                (pipe, FcKernel::I8Acc16 { packed, x_qp })
            }
        };
        FcLayer { n, k, precision, pipe, kernel, ctx: GemmCtx::auto() }
    }

    /// Build an acc16 layer from already-quantized int8 weights with a
    /// configurable main-path bit width — the outlier-threshold ablation
    /// knob (§3.2.1), exposed so the ablation bench drives the same
    /// dispatch path serving does.
    #[allow(clippy::too_many_arguments)]
    pub fn i8acc16_from_quantized(
        w_q: &[i8],
        n: usize,
        k: usize,
        main_bits: u32,
        x_qp: QParams,
        w_scale: f32,
        bias: Option<&[f32]>,
        relu: bool,
    ) -> FcLayer {
        assert_eq!(w_q.len(), n * k);
        let bias_v = bias.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; n]);
        let packed = PackedBI8Acc16::pack_bits(w_q, n, k, main_bits);
        let pipe = OutputPipeline {
            x_zp: x_qp.zero_point,
            scale: vec![w_scale * x_qp.scale; n],
            b_rowsum: packed.rowsum.clone(),
            bias: bias_v,
            relu,
        };
        FcLayer {
            n,
            k,
            precision: Precision::I8Acc16,
            pipe,
            kernel: FcKernel::I8Acc16 { packed, x_qp },
            ctx: GemmCtx::auto(),
        }
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Kernel execution context (ISA variant + intra-op threads).
    pub fn gemm_ctx(&self) -> GemmCtx {
        self.ctx
    }

    /// Override the kernel execution context — the benches use this to
    /// A/B scalar vs SIMD vs threaded on the same packed layer.
    pub fn set_gemm_ctx(&mut self, ctx: GemmCtx) {
        self.ctx = ctx;
    }

    /// Builder form of [`FcLayer::set_gemm_ctx`].
    pub fn with_gemm_ctx(mut self, ctx: GemmCtx) -> FcLayer {
        self.ctx = ctx;
        self
    }

    /// Outlier density of the acc16 sparse residual (None on other paths).
    pub fn outlier_density(&self) -> Option<f64> {
        match &self.kernel {
            FcKernel::I8Acc16 { packed, .. } => Some(packed.outliers.density()),
            _ => None,
        }
    }

    /// `out[M x N] = pipeline(x[M x K] * W^T)`; int8 paths quantize the
    /// fp32 activations with the layer's calibrated qparams first (into
    /// a reused thread-local scratch — no steady-state allocation).
    pub fn forward(&self, x: &[f32], m: usize, out: &mut [f32]) {
        self.forward_ep(x, m, &[], out)
    }

    /// [`FcLayer::forward`] with a folded elementwise tail applied at
    /// kernel write-out (compiled-plan epilogue fusion): every output
    /// element passes through the output pipeline and then each
    /// [`TailOp`] in order before it is stored, so an
    /// `fc -> unary -> binary` chain executes as one kernel pass with
    /// no intermediate materialization.
    pub fn forward_ep(&self, x: &[f32], m: usize, tail: &[TailOp<'_>], out: &mut [f32]) {
        assert_eq!(x.len(), m * self.k);
        assert_eq!(out.len(), m * self.n);
        let ep = Epilogue { pipe: &self.pipe, tail };
        match &self.kernel {
            FcKernel::F32(p) => gemm_f32_ep(&self.ctx, x, m, p, &ep, out),
            FcKernel::F16(p) => gemm_f16_ep(&self.ctx, x, m, p, &ep, out),
            FcKernel::I8 { packed, x_qp } => QUANT_SCRATCH.with(|buf| {
                let mut xq = buf.borrow_mut();
                x_qp.quantize_into(x, &mut xq);
                gemm_i8_acc32_ep(&self.ctx, &xq, m, packed, &ep, out);
            }),
            FcKernel::I8Acc16 { packed, x_qp } => QUANT_SCRATCH.with(|buf| {
                let mut xq = buf.borrow_mut();
                x_qp.quantize_into(x, &mut xq);
                gemm_i8_acc16_ep(&self.ctx, &xq, m, packed, &ep, out);
            }),
        }
    }
}

// ---------------------------------------------------------------------------
// Program spec (parsed JSON) and compiled form
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Activation {
    Identity,
    Relu,
    Sigmoid,
    Tanh,
}

impl Activation {
    fn parse(s: &str) -> Result<Activation> {
        Ok(match s {
            "none" => Activation::Identity,
            "relu" => Activation::Relu,
            "sigmoid" => Activation::Sigmoid,
            "tanh" => Activation::Tanh,
            other => bail!("unknown activation {other}"),
        })
    }

    fn relu(self) -> bool {
        self == Activation::Relu
    }

    fn post(self) -> Option<UnaryFn> {
        match self {
            Activation::Sigmoid => Some(UnaryFn::Sigmoid),
            Activation::Tanh => Some(UnaryFn::Tanh),
            _ => None,
        }
    }
}

/// Elementwise unary op the interpreter (and the compiled-plan tail
/// lowering) dispatches on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum UnaryFn {
    Relu,
    Sigmoid,
    Tanh,
    OneMinus,
}

impl UnaryFn {
    fn parse(s: &str) -> Result<UnaryFn> {
        Ok(match s {
            "relu" => UnaryFn::Relu,
            "sigmoid" => UnaryFn::Sigmoid,
            "tanh" => UnaryFn::Tanh,
            "one_minus" => UnaryFn::OneMinus,
            other => bail!("unknown unary fn {other}"),
        })
    }

    fn apply(self, xs: &mut [f32]) {
        match self {
            UnaryFn::Relu => xs.iter_mut().for_each(|v| *v = v.max(0.0)),
            UnaryFn::Sigmoid => xs.iter_mut().for_each(|v| *v = 1.0 / (1.0 + (-*v).exp())),
            UnaryFn::Tanh => xs.iter_mut().for_each(|v| *v = v.tanh()),
            UnaryFn::OneMinus => xs.iter_mut().for_each(|v| *v = 1.0 - *v),
        }
    }
}

/// Elementwise binary op (same dispatch story as [`UnaryFn`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BinaryFn {
    Add,
    Mul,
}

impl BinaryFn {
    fn parse(s: &str) -> Result<BinaryFn> {
        Ok(match s {
            "add" => BinaryFn::Add,
            "mul" => BinaryFn::Mul,
            other => bail!("unknown binary fn {other}"),
        })
    }
}

/// One parsed program op (the manifest's JSON form).
#[derive(Debug, Clone)]
pub(crate) enum OpSpec {
    Fc { out: String, input: String, w: String, b: Option<String>, act: Activation },
    Conv2d {
        out: String,
        input: String,
        w: String,
        b: Option<String>,
        act: Activation,
        stride: usize,
        pad: (usize, usize),
    },
    EmbedPool { out: String, indices: String, table: String, slice: Option<usize> },
    Concat { out: String, inputs: Vec<String> },
    Unary { out: String, input: String, f: UnaryFn },
    Binary { out: String, a: String, b: String, f: BinaryFn },
    Flatten { out: String, input: String },
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key).as_str().with_context(|| format!("program op missing field {key:?}"))?.to_string())
}

fn opt_str(j: &Json, key: &str) -> Option<String> {
    j.get(key).as_str().map(|s| s.to_string())
}

impl OpSpec {
    fn parse(j: &Json) -> Result<OpSpec> {
        let op = j.get("op").as_str().context("program op missing \"op\"")?;
        let out = req_str(j, "out")?;
        Ok(match op {
            "fc" => OpSpec::Fc {
                out,
                input: req_str(j, "in")?,
                w: req_str(j, "w")?,
                b: opt_str(j, "b"),
                act: Activation::parse(j.get("act").as_str().unwrap_or("none"))?,
            },
            "conv2d" => {
                let pad = j.get("pad").as_arr().context("conv2d pad")?;
                ensure!(pad.len() == 2, "conv2d pad must be [lo, hi]");
                OpSpec::Conv2d {
                    out,
                    input: req_str(j, "in")?,
                    w: req_str(j, "w")?,
                    b: opt_str(j, "b"),
                    act: Activation::parse(j.get("act").as_str().unwrap_or("none"))?,
                    stride: j.get("stride").as_usize().context("conv2d stride")?,
                    pad: (
                        pad[0].as_usize().context("pad lo")?,
                        pad[1].as_usize().context("pad hi")?,
                    ),
                }
            }
            "embed_pool" => OpSpec::EmbedPool {
                out,
                indices: req_str(j, "indices")?,
                table: req_str(j, "table")?,
                slice: j.get("slice").as_usize(),
            },
            "concat" => OpSpec::Concat {
                out,
                inputs: j
                    .get("in")
                    .as_arr()
                    .context("concat in")?
                    .iter()
                    .map(|v| v.as_str().context("concat input name").map(|s| s.to_string()))
                    .collect::<Result<Vec<_>>>()?,
            },
            "unary" => OpSpec::Unary {
                out,
                input: req_str(j, "in")?,
                f: UnaryFn::parse(j.get("fn").as_str().context("unary fn")?)?,
            },
            "binary" => OpSpec::Binary {
                out,
                a: req_str(j, "a")?,
                b: req_str(j, "b")?,
                f: BinaryFn::parse(j.get("fn").as_str().context("binary fn")?)?,
            },
            "flatten" => OpSpec::Flatten { out, input: req_str(j, "in")? },
            other => bail!("unknown program op {other:?}"),
        })
    }
}

fn parse_program(j: &Json) -> Result<Vec<OpSpec>> {
    let arr = j
        .as_arr()
        .context("artifact has no native op program (rebuild artifacts with the current aot.py)")?;
    ensure!(!arr.is_empty(), "empty native op program");
    arr.iter().map(OpSpec::parse).collect()
}

/// Embedding table at the backend's precision: local (per-executor
/// copy) or shared through the dis-aggregated sparse tier.
enum PoolTable {
    F32(EmbeddingTable),
    Q(QuantizedTable),
    Shared { tier: Arc<EmbeddingShardService>, id: usize, rows: usize, dim: usize },
}

impl PoolTable {
    fn dims(&self) -> (usize, usize) {
        match self {
            PoolTable::F32(t) => (t.rows, t.dim),
            PoolTable::Q(t) => (t.rows, t.dim),
            PoolTable::Shared { rows, dim, .. } => (*rows, *dim),
        }
    }

    fn pool(&self, batch: &LookupBatch, out: &mut [f32]) -> Result<()> {
        match self {
            PoolTable::F32(t) => {
                t.sparse_lengths_sum(batch, out);
                Ok(())
            }
            PoolTable::Q(t) => {
                t.sparse_lengths_sum(batch, out);
                Ok(())
            }
            PoolTable::Shared { tier, id, .. } => tier.lookup(*id, batch, out),
        }
    }
}

// ---------------------------------------------------------------------------
// Execution plan: registers resolved to dense, statically-sized slots
// ---------------------------------------------------------------------------

/// One planned f32 register. `parent` makes the slot a view of another
/// (flatten aliases, in-place unary); buffer ownership follows the
/// parent chain to the canonical slot.
pub(crate) struct Slot {
    pub(crate) len: usize,
    pub(crate) parent: Option<usize>,
}

/// Where each artifact input lands in the arena.
enum InputDst {
    F32(usize),
    I32(usize),
}

/// Build-time resolution of register names to dense arena slots, with
/// every buffer size precomputed from the artifact's fixed shapes.
pub(crate) struct Plan {
    pub(crate) slots: Vec<Slot>,
    /// i32 index inputs (no op produces integers)
    int_lens: Vec<usize>,
    input_dst: Vec<InputDst>,
    /// canonical f32 slot backing each artifact output
    output_src: Vec<usize>,
    /// (bags, pool) per embed op, in op order — sizes the reusable
    /// lookup batches
    lookup_dims: Vec<(usize, usize)>,
}

impl Plan {
    pub(crate) fn canon(&self, mut s: usize) -> usize {
        while let Some(p) = self.slots[s].parent {
            s = p;
        }
        s
    }
}

/// im2col geometry, fixed at build time.
pub(crate) struct ConvGeom {
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    plo: usize,
    ho: usize,
    wo: usize,
    pub(crate) rows: usize,
}

/// Compiled op: packed weights + canonical arena slot indices.
pub(crate) enum CompiledOp {
    Fc {
        out: usize,
        input: usize,
        m: usize,
        layer: FcLayer,
        post: Option<UnaryFn>,
        spec_idx: usize,
    },
    Conv2d {
        out: usize,
        input: usize,
        layer: FcLayer,
        post: Option<UnaryFn>,
        geom: ConvGeom,
        col: usize,
        gbuf: usize,
        spec_idx: usize,
    },
    EmbedPool {
        out: usize,
        indices: usize,
        table: usize,
        slice: Option<usize>,
        /// tables dimension of the index tensor (1 when unsliced)
        nt: usize,
        bags: usize,
        pool: usize,
        rows: usize,
        lb: usize,
    },
    Concat { out: usize, inputs: Vec<usize>, b: usize, widths: Vec<usize> },
    Unary { out: usize, input: usize, f: UnaryFn, in_place: bool },
    Binary { out: usize, a: usize, b: usize, f: BinaryFn },
    // flatten compiles away entirely: its output is an alias slot
}

/// The reusable per-artifact execution state: one preallocated buffer
/// per canonical slot plus per-embed-op lookup batches. All sizes are
/// fixed at build time, so steady-state execution never allocates.
pub struct ExecArena {
    pub(crate) bufs: Vec<Vec<f32>>,
    int_bufs: Vec<Vec<i32>>,
    lookups: Vec<LookupBatch>,
}

pub(crate) struct CompiledProgram {
    pub(crate) ops: Vec<CompiledOp>,
    tables: Vec<PoolTable>,
    pub(crate) plan: Plan,
}

fn weight<'a>(
    weights: &'a HashMap<String, &HostTensor>,
    name: &str,
) -> Result<&'a HostTensor> {
    weights.get(name).copied().with_context(|| format!("weight {name} missing from weights file"))
}

fn push_slot(slots: &mut Vec<Slot>, shape: &[usize], parent: Option<usize>) -> usize {
    slots.push(Slot { len: shape.iter().product(), parent });
    slots.len() - 1
}

impl CompiledProgram {
    /// Pack every layer of `spec` at `precision` and plan the register
    /// arena from the artifact's fixed input shapes. `act_qparams` maps
    /// spec-op index -> calibrated activation qparams (required for
    /// int8). With `sparse` set, embedding tables are registered into
    /// (and fetched through) the shared sparse tier instead of being
    /// copied into this executor; `scope` namespaces their keys so
    /// same-named tables of different model families don't collide.
    /// `threads` is the intra-op fan-out every packed layer runs with.
    #[allow(clippy::too_many_arguments)]
    fn build(
        spec: &[OpSpec],
        meta: &ArtifactMeta,
        weights: &HashMap<String, &HostTensor>,
        precision: Precision,
        act_qparams: Option<&HashMap<usize, QParams>>,
        sparse: Option<&Arc<EmbeddingShardService>>,
        scope: &str,
        threads: usize,
    ) -> Result<CompiledProgram> {
        let int8 = matches!(precision, Precision::I8Acc32 | Precision::I8Acc16);
        let gemm_ctx = GemmCtx::threaded(threads); // 0 = all available cores
        let qp_for = |i: usize| -> QParams {
            act_qparams
                .and_then(|m| m.get(&i).copied())
                // pre-calibration fp32 builds never read this
                .unwrap_or_else(|| QParams::from_range(-1.0, 1.0, 8, false))
        };

        // --- register slots seeded from the artifact inputs ---------
        let mut slots: Vec<Slot> = Vec::new();
        let mut shapes: Vec<Vec<usize>> = Vec::new(); // per slot, build-time only
        let mut int_lens: Vec<usize> = Vec::new();
        let mut int_shapes: Vec<Vec<usize>> = Vec::new();
        let mut f32_of: HashMap<String, usize> = HashMap::new();
        let mut i32_of: HashMap<String, usize> = HashMap::new();
        let mut input_dst = Vec::with_capacity(meta.inputs.len());
        for im in &meta.inputs {
            match im.dtype {
                DType::F32 => {
                    let s = push_slot(&mut slots, &im.shape, None);
                    shapes.push(im.shape.clone());
                    f32_of.insert(im.name.clone(), s);
                    input_dst.push(InputDst::F32(s));
                }
                DType::I32 => {
                    int_lens.push(im.shape.iter().product());
                    int_shapes.push(im.shape.clone());
                    i32_of.insert(im.name.clone(), int_lens.len() - 1);
                    input_dst.push(InputDst::I32(int_lens.len() - 1));
                }
                DType::I8 => bail!("native backend: i8 inputs unsupported ({})", im.name),
            }
        }
        let fslot = |map: &HashMap<String, usize>, name: &str| -> Result<usize> {
            map.get(name)
                .copied()
                .with_context(|| format!("program references undefined tensor {name:?}"))
        };

        let mut ops: Vec<CompiledOp> = Vec::new();
        let mut tables: Vec<PoolTable> = Vec::new();
        let mut table_idx: HashMap<String, usize> = HashMap::new();
        let mut lookup_dims: Vec<(usize, usize)> = Vec::new();
        for (i, op) in spec.iter().enumerate() {
            if int8 {
                ensure!(
                    !matches!(op, OpSpec::Fc { .. } | OpSpec::Conv2d { .. })
                        || act_qparams.map(|m| m.contains_key(&i)).unwrap_or(false),
                    "op {i} has no calibrated activation qparams"
                );
            }
            match op {
                OpSpec::Fc { out, input, w, b, act } => {
                    let wt = weight(weights, w)?;
                    ensure!(wt.shape.len() == 2, "fc weight {w} must be 2-D, got {:?}", wt.shape);
                    let (n, k) = (wt.shape[0], wt.shape[1]);
                    let x = fslot(&f32_of, input)?;
                    ensure!(!shapes[x].is_empty(), "fc input {input} is scalar");
                    let m = shapes[x][0];
                    let feat: usize = shapes[x][1..].iter().product();
                    ensure!(
                        feat == k,
                        "fc {out}: input {input} has {feat} features, weight wants {k}"
                    );
                    let wdata = wt.as_f32()?;
                    let bias = match b {
                        Some(bn) => Some(weight(weights, bn)?.as_f32()?),
                        None => None,
                    };
                    let layer = FcLayer::from_f32(
                        precision,
                        &wdata,
                        n,
                        k,
                        bias.as_deref(),
                        act.relu(),
                        qp_for(i),
                    )
                    .with_gemm_ctx(gemm_ctx);
                    let o = push_slot(&mut slots, &[m, n], None);
                    shapes.push(vec![m, n]);
                    f32_of.insert(out.clone(), o);
                    ops.push(CompiledOp::Fc {
                        out: o,
                        input: x,
                        m,
                        layer,
                        post: act.post(),
                        spec_idx: i,
                    });
                }
                OpSpec::Conv2d { out, input, w, b, act, stride, pad } => {
                    let wt = weight(weights, w)?;
                    ensure!(
                        wt.shape.len() == 4,
                        "conv2d weight {w} must be [co, ci, kh, kw], got {:?}",
                        wt.shape
                    );
                    let (co, kh, kw) = (wt.shape[0], wt.shape[2], wt.shape[3]);
                    let k = wt.shape[1] * kh * kw;
                    let x = fslot(&f32_of, input)?;
                    ensure!(
                        shapes[x].len() == 4,
                        "conv2d {out}: input {input} must be [B,C,H,W]"
                    );
                    let (bsz, c, h, wdim) =
                        (shapes[x][0], shapes[x][1], shapes[x][2], shapes[x][3]);
                    ensure!(
                        k == c * kh * kw,
                        "conv2d {out}: weight K {k} != C*kh*kw {}",
                        c * kh * kw
                    );
                    let (plo, phi) = *pad;
                    ensure!(
                        h + plo + phi >= kh && wdim + plo + phi >= kw,
                        "conv2d {out}: kernel exceeds input"
                    );
                    let ho = (h + plo + phi - kh) / stride + 1;
                    let wo = (wdim + plo + phi - kw) / stride + 1;
                    let rows = bsz * ho * wo;
                    let wdata = wt.as_f32()?;
                    let bias = match b {
                        Some(bn) => Some(weight(weights, bn)?.as_f32()?),
                        None => None,
                    };
                    let layer = FcLayer::from_f32(
                        precision,
                        &wdata,
                        co,
                        k,
                        bias.as_deref(),
                        act.relu(),
                        qp_for(i),
                    )
                    .with_gemm_ctx(gemm_ctx);
                    // im2col + gemm scratch slots (anonymous, preallocated)
                    let col = push_slot(&mut slots, &[rows, k], None);
                    shapes.push(vec![rows, k]);
                    let gbuf = push_slot(&mut slots, &[rows, co], None);
                    shapes.push(vec![rows, co]);
                    let o = push_slot(&mut slots, &[bsz, co, ho, wo], None);
                    shapes.push(vec![bsz, co, ho, wo]);
                    f32_of.insert(out.clone(), o);
                    ops.push(CompiledOp::Conv2d {
                        out: o,
                        input: x,
                        layer,
                        post: act.post(),
                        geom: ConvGeom {
                            b: bsz,
                            c,
                            h,
                            w: wdim,
                            kh,
                            kw,
                            stride: *stride,
                            plo,
                            ho,
                            wo,
                            rows,
                        },
                        col,
                        gbuf,
                        spec_idx: i,
                    });
                }
                OpSpec::EmbedPool { out, indices, table, slice } => {
                    let idx = match table_idx.get(table).copied() {
                        Some(t) => t,
                        None => {
                            let wt = weight(weights, table)?;
                            ensure!(
                                wt.shape.len() == 2,
                                "embedding table {table} must be 2-D, got {:?}",
                                wt.shape
                            );
                            let t = EmbeddingTable::new(wt.shape[0], wt.shape[1], wt.as_f32()?);
                            tables.push(match sparse {
                                Some(tier) => {
                                    let key = format!("{scope}/{table}");
                                    let id = tier.register_table(&key, &t, int8)?;
                                    PoolTable::Shared {
                                        tier: tier.clone(),
                                        id,
                                        rows: t.rows,
                                        dim: t.dim,
                                    }
                                }
                                None if int8 => PoolTable::Q(QuantizedTable::from_f32(&t)),
                                None => PoolTable::F32(t),
                            });
                            table_idx.insert(table.clone(), tables.len() - 1);
                            tables.len() - 1
                        }
                    };
                    let islot = fslot(&i32_of, indices)
                        .with_context(|| format!("embed_pool: no i32 input named {indices}"))?;
                    let ishape = &int_shapes[islot];
                    let (nt, bags, pool) = match slice {
                        Some(t) => {
                            ensure!(
                                ishape.len() == 3 && *t < ishape[1],
                                "embed_pool slice {t} out of {indices} shape {ishape:?}"
                            );
                            (ishape[1], ishape[0], ishape[2])
                        }
                        None => {
                            ensure!(ishape.len() == 2, "embed_pool: {indices} must be [B, pool]");
                            (1, ishape[0], ishape[1])
                        }
                    };
                    let (rows, dim) = tables[idx].dims();
                    let o = push_slot(&mut slots, &[bags, dim], None);
                    shapes.push(vec![bags, dim]);
                    f32_of.insert(out.clone(), o);
                    lookup_dims.push((bags, pool));
                    ops.push(CompiledOp::EmbedPool {
                        out: o,
                        indices: islot,
                        table: idx,
                        slice: *slice,
                        nt,
                        bags,
                        pool,
                        rows,
                        lb: lookup_dims.len() - 1,
                    });
                }
                OpSpec::Concat { out, inputs } => {
                    ensure!(!inputs.is_empty(), "concat with no inputs");
                    let parts = inputs
                        .iter()
                        .map(|nm| fslot(&f32_of, nm))
                        .collect::<Result<Vec<_>>>()?;
                    let b = shapes[parts[0]][0];
                    let mut widths = Vec::with_capacity(parts.len());
                    for (s, nm) in parts.iter().zip(inputs) {
                        ensure!(
                            shapes[*s].len() == 2 && shapes[*s][0] == b,
                            "concat input {nm} shape {:?} (want [{b}, _])",
                            shapes[*s]
                        );
                        widths.push(shapes[*s][1]);
                    }
                    let total: usize = widths.iter().sum();
                    let o = push_slot(&mut slots, &[b, total], None);
                    shapes.push(vec![b, total]);
                    f32_of.insert(out.clone(), o);
                    ops.push(CompiledOp::Concat { out: o, inputs: parts, b, widths });
                }
                OpSpec::Unary { out, input, f } => {
                    let x = fslot(&f32_of, input)?;
                    let o = push_slot(&mut slots, &shapes[x].clone(), None);
                    shapes.push(shapes[x].clone());
                    f32_of.insert(out.clone(), o);
                    ops.push(CompiledOp::Unary { out: o, input: x, f: *f, in_place: false });
                }
                OpSpec::Binary { out, a, b, f } => {
                    let sa = fslot(&f32_of, a)?;
                    let sb = fslot(&f32_of, b)?;
                    ensure!(
                        shapes[sa] == shapes[sb],
                        "binary {out}: {a} {:?} vs {b} {:?}",
                        shapes[sa],
                        shapes[sb]
                    );
                    let o = push_slot(&mut slots, &shapes[sa].clone(), None);
                    shapes.push(shapes[sa].clone());
                    f32_of.insert(out.clone(), o);
                    ops.push(CompiledOp::Binary { out: o, a: sa, b: sb, f: *f });
                }
                OpSpec::Flatten { out, input } => {
                    let x = fslot(&f32_of, input)?;
                    ensure!(!shapes[x].is_empty(), "flatten of scalar {input}");
                    let rest: usize = shapes[x][1..].iter().product();
                    // pure view: aliases the input's buffer, zero runtime cost
                    let o = push_slot(&mut slots, &[shapes[x][0], rest], Some(x));
                    shapes.push(vec![shapes[x][0], rest]);
                    f32_of.insert(out.clone(), o);
                }
            }
        }

        // --- artifact outputs: resolve + validate shape statically ---
        let mut output_src = Vec::with_capacity(meta.outputs.len());
        for om in &meta.outputs {
            ensure!(om.dtype == DType::F32, "native backend: output {} must be f32", om.name);
            let s = *f32_of
                .get(&om.name)
                .with_context(|| format!("program never produced output {:?}", om.name))?;
            ensure!(
                shapes[s] == om.shape,
                "output {}: program shape {:?} != manifest {:?}",
                om.name,
                shapes[s],
                om.shape
            );
            output_src.push(s);
        }

        let mut plan = Plan { slots, int_lens, input_dst, output_src, lookup_dims };

        // --- in-place unary analysis: last reader wins the buffer ----
        // last spec-order position each canonical slot is read at;
        // artifact outputs are "read" at the very end.
        let mut last_read: Vec<usize> = vec![0; plan.slots.len()];
        for (oi, op) in ops.iter().enumerate() {
            let mut mark = |s: usize, lr: &mut Vec<usize>| {
                let c = plan.canon(s);
                lr[c] = lr[c].max(oi + 1); // 1-based so 0 means "never read"
            };
            match op {
                CompiledOp::Fc { input, .. } => mark(*input, &mut last_read),
                CompiledOp::Conv2d { input, .. } => mark(*input, &mut last_read),
                CompiledOp::EmbedPool { .. } => {}
                CompiledOp::Concat { inputs, .. } => {
                    for s in inputs {
                        mark(*s, &mut last_read);
                    }
                }
                CompiledOp::Unary { input, .. } => mark(*input, &mut last_read),
                CompiledOp::Binary { a, b, .. } => {
                    mark(*a, &mut last_read);
                    mark(*b, &mut last_read);
                }
            }
        }
        for s in &plan.output_src {
            last_read[plan.canon(*s)] = usize::MAX;
        }
        for (oi, op) in ops.iter_mut().enumerate() {
            if let CompiledOp::Unary { out, input, in_place, .. } = op {
                let cin = plan.canon(*input);
                let cout = plan.canon(*out);
                if last_read[cin] == oi + 1 && cin != cout {
                    // this unary is the input's final reader: mutate in
                    // place and make the output a view of the input
                    plan.slots[cout].parent = Some(cin);
                    last_read[cin] = last_read[cout];
                    *in_place = true;
                }
            }
        }

        // --- canonicalize every op reference for execution ------------
        for op in ops.iter_mut() {
            match op {
                CompiledOp::Fc { out, input, .. } => {
                    *out = plan.canon(*out);
                    *input = plan.canon(*input);
                }
                CompiledOp::Conv2d { out, input, col, gbuf, .. } => {
                    *out = plan.canon(*out);
                    *input = plan.canon(*input);
                    *col = plan.canon(*col);
                    *gbuf = plan.canon(*gbuf);
                }
                CompiledOp::EmbedPool { out, .. } => *out = plan.canon(*out),
                CompiledOp::Concat { out, inputs, .. } => {
                    *out = plan.canon(*out);
                    for s in inputs.iter_mut() {
                        *s = plan.canon(*s);
                    }
                }
                CompiledOp::Unary { out, input, .. } => {
                    *out = plan.canon(*out);
                    *input = plan.canon(*input);
                }
                CompiledOp::Binary { out, a, b, .. } => {
                    *out = plan.canon(*out);
                    *a = plan.canon(*a);
                    *b = plan.canon(*b);
                }
            }
        }
        let canon_out: Vec<usize> = plan.output_src.iter().map(|s| plan.canon(*s)).collect();
        plan.output_src = canon_out;

        Ok(CompiledProgram { ops, tables, plan })
    }

    /// Allocate a fresh arena sized by the plan (all buffers at their
    /// final capacity; done once per executor at load time).
    fn new_arena(&self) -> ExecArena {
        let bufs = self
            .plan
            .slots
            .iter()
            .map(|s| if s.parent.is_none() { vec![0f32; s.len] } else { Vec::new() })
            .collect();
        let int_bufs = self.plan.int_lens.iter().map(|&l| vec![0i32; l]).collect();
        let lookups = self
            .plan
            .lookup_dims
            .iter()
            .map(|&(bags, pool)| LookupBatch {
                indices: Vec::with_capacity(bags * pool),
                lengths: vec![pool as u32; bags],
            })
            .collect();
        ExecArena { bufs, int_bufs, lookups }
    }

    /// Interpret the program into `arena` (zero heap allocations once
    /// the arena is warm). `observers` (calibration mode) record the
    /// fp32 input distribution of every fc/conv op by spec index.
    fn execute_in(
        &self,
        meta: &ArtifactMeta,
        inputs: &[HostTensor],
        arena: &mut ExecArena,
        mut observers: Option<&mut HashMap<usize, Calibrator>>,
    ) -> Result<()> {
        self.decode_inputs(meta, inputs, arena)?;

        for (i, op) in self.ops.iter().enumerate() {
            match op {
                CompiledOp::Fc { out, input, m, layer, post, spec_idx } => {
                    debug_assert_ne!(out, input);
                    let mut o = mem::take(&mut arena.bufs[*out]);
                    {
                        let x = &arena.bufs[*input];
                        if let Some(obs) = observers.as_deref_mut() {
                            obs.entry(*spec_idx).or_insert_with(Calibrator::default).observe(x);
                        }
                        layer.forward(x, *m, &mut o);
                    }
                    if let Some(f) = post {
                        f.apply(&mut o);
                    }
                    arena.bufs[*out] = o;
                }
                CompiledOp::Conv2d { out, input, layer, post, geom, col, gbuf, spec_idx } => {
                    let mut colb = mem::take(&mut arena.bufs[*col]);
                    let mut gb = mem::take(&mut arena.bufs[*gbuf]);
                    let mut o = mem::take(&mut arena.bufs[*out]);
                    {
                        let x = &arena.bufs[*input];
                        if let Some(obs) = observers.as_deref_mut() {
                            obs.entry(*spec_idx).or_insert_with(Calibrator::default).observe(x);
                        }
                        // padding positions of the col buffer are never
                        // written: they were zeroed at arena build and
                        // the written set is geometry-fixed per batch
                        im2col(x, geom, layer.k, &mut colb);
                        layer.forward(&colb, geom.rows, &mut gb);
                        if let Some(f) = post {
                            f.apply(&mut gb);
                        }
                        nchw_scatter(&gb, geom, layer.n, &mut o);
                    }
                    arena.bufs[*col] = colb;
                    arena.bufs[*gbuf] = gb;
                    arena.bufs[*out] = o;
                }
                CompiledOp::EmbedPool { .. } => self.exec_embed_at(i, arena)?,
                CompiledOp::Concat { .. } => self.exec_concat_at(i, arena),
                CompiledOp::Unary { .. } => self.exec_unary_at(i, arena),
                CompiledOp::Binary { .. } => self.exec_binary_at(i, arena),
            }
        }
        Ok(())
    }

    /// Decode the artifact inputs into their arena slots (shared by the
    /// interpreter and the compiled plan).
    pub(crate) fn decode_inputs(
        &self,
        meta: &ArtifactMeta,
        inputs: &[HostTensor],
        arena: &mut ExecArena,
    ) -> Result<()> {
        check_inputs(meta, inputs)?;
        for (t, dst) in inputs.iter().zip(&self.plan.input_dst) {
            match *dst {
                InputDst::F32(s) => t.copy_f32_into(&mut arena.bufs[s])?,
                InputDst::I32(s) => t.copy_i32_into(&mut arena.int_bufs[s])?,
            }
        }
        Ok(())
    }

    /// Execute the `embed_pool` op at index `i` (shared by the
    /// interpreter loop and the compiled plan's step table).
    pub(crate) fn exec_embed_at(&self, i: usize, arena: &mut ExecArena) -> Result<()> {
        let CompiledOp::EmbedPool { out, indices, table, slice, nt, bags, pool, rows, lb } =
            &self.ops[i]
        else {
            unreachable!("exec_embed_at bound to a non-embed op");
        };
        // fill + validate the reusable lookup batch before touching the
        // output buffer, so failed batches leave the arena intact
        {
            let idx = &arena.int_bufs[*indices];
            let lbatch = &mut arena.lookups[*lb];
            lbatch.indices.clear();
            match slice {
                Some(t) => {
                    for bi in 0..*bags {
                        let base = (bi * nt + t) * pool;
                        for &v in &idx[base..base + pool] {
                            ensure!(
                                v >= 0 && (v as usize) < *rows,
                                "embedding index {v} out of range 0..{rows}"
                            );
                            lbatch.indices.push(v as u32);
                        }
                    }
                }
                None => {
                    for &v in idx.iter() {
                        ensure!(
                            v >= 0 && (v as usize) < *rows,
                            "embedding index {v} out of range 0..{rows}"
                        );
                        lbatch.indices.push(v as u32);
                    }
                }
            }
        }
        let mut o = mem::take(&mut arena.bufs[*out]);
        let res = self.tables[*table].pool(&arena.lookups[*lb], &mut o);
        arena.bufs[*out] = o;
        res
    }

    /// Execute the `concat` op at index `i`.
    pub(crate) fn exec_concat_at(&self, i: usize, arena: &mut ExecArena) {
        let CompiledOp::Concat { out, inputs, b, widths } = &self.ops[i] else {
            unreachable!("exec_concat_at bound to a non-concat op");
        };
        let mut o = mem::take(&mut arena.bufs[*out]);
        {
            let total: usize = widths.iter().sum();
            for bi in 0..*b {
                let mut off = 0usize;
                for (s, w) in inputs.iter().zip(widths) {
                    let src = &arena.bufs[*s];
                    o[bi * total + off..bi * total + off + w]
                        .copy_from_slice(&src[bi * w..(bi + 1) * w]);
                    off += w;
                }
            }
        }
        arena.bufs[*out] = o;
    }

    /// Execute the `unary` op at index `i`.
    pub(crate) fn exec_unary_at(&self, i: usize, arena: &mut ExecArena) {
        let CompiledOp::Unary { out, input, f, in_place } = &self.ops[i] else {
            unreachable!("exec_unary_at bound to a non-unary op");
        };
        if *in_place {
            // out aliases input's buffer (final reader)
            f.apply(&mut arena.bufs[*out]);
        } else {
            let mut o = mem::take(&mut arena.bufs[*out]);
            o.copy_from_slice(&arena.bufs[*input]);
            f.apply(&mut o);
            arena.bufs[*out] = o;
        }
    }

    /// Execute the `binary` op at index `i`.
    pub(crate) fn exec_binary_at(&self, i: usize, arena: &mut ExecArena) {
        let CompiledOp::Binary { out, a, b, f } = &self.ops[i] else {
            unreachable!("exec_binary_at bound to a non-binary op");
        };
        let mut o = mem::take(&mut arena.bufs[*out]);
        {
            let xa = &arena.bufs[*a];
            let xb = &arena.bufs[*b];
            match f {
                BinaryFn::Add => {
                    for ((dst, x), y) in o.iter_mut().zip(xa.iter()).zip(xb.iter()) {
                        *dst = x + y;
                    }
                }
                BinaryFn::Mul => {
                    for ((dst, x), y) in o.iter_mut().zip(xa.iter()).zip(xb.iter()) {
                        *dst = x * y;
                    }
                }
            }
        }
        arena.bufs[*out] = o;
    }
}

/// im2col into the preallocated scratch (padding stays zero — see the
/// call site).
pub(crate) fn im2col(x: &[f32], g: &ConvGeom, k_per_row: usize, col: &mut [f32]) {
    for bi in 0..g.b {
        for y in 0..g.ho {
            for xx in 0..g.wo {
                let row = ((bi * g.ho + y) * g.wo + xx) * k_per_row;
                let mut off = 0usize;
                for ci in 0..g.c {
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            let iy = (y * g.stride + ky) as isize - g.plo as isize;
                            let ix = (xx * g.stride + kx) as isize - g.plo as isize;
                            if iy >= 0 && (iy as usize) < g.h && ix >= 0 && (ix as usize) < g.w {
                                col[row + off] = x
                                    [((bi * g.c + ci) * g.h + iy as usize) * g.w + ix as usize];
                            }
                            off += 1;
                        }
                    }
                }
            }
        }
    }
}

/// `[B*ho*wo, co]` GEMM output back to NCHW.
pub(crate) fn nchw_scatter(gemm_out: &[f32], g: &ConvGeom, n: usize, out: &mut [f32]) {
    for bi in 0..g.b {
        for y in 0..g.ho {
            for xx in 0..g.wo {
                let src = ((bi * g.ho + y) * g.wo + xx) * n;
                for co in 0..n {
                    out[((bi * n + co) * g.ho + y) * g.wo + xx] = gemm_out[src + co];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Calibration (§3.2.2 techniques 1 & 4)
// ---------------------------------------------------------------------------

/// Deterministic synthetic calibration inputs matching the artifact's
/// input metas; i32 inputs draw below the smallest table they feed.
fn synth_calibration_inputs(
    meta: &ArtifactMeta,
    index_bounds: &HashMap<String, usize>,
    seed: u64,
) -> Vec<HostTensor> {
    let mut rng = Pcg32::seeded(seed);
    meta.inputs
        .iter()
        .map(|im| match im.dtype {
            DType::I32 => {
                let hi = *index_bounds.get(&im.name).unwrap_or(&1);
                let vals: Vec<i32> =
                    (0..im.elem_count()).map(|_| rng.below(hi.max(1) as u32) as i32).collect();
                HostTensor::from_i32(&im.shape, &vals)
            }
            _ => {
                let mut vals = vec![0f32; im.elem_count()];
                rng.fill_normal(&mut vals, 0.0, 1.0);
                HostTensor::from_f32(&im.shape, &vals)
            }
        })
        .collect()
}

/// Observe every fc/conv input through the fp32 program and pick
/// L2-optimal activation qparams per layer.
fn calibrate(
    fp32: &CompiledProgram,
    meta: &ArtifactMeta,
    index_bounds: &HashMap<String, usize>,
) -> Result<HashMap<usize, QParams>> {
    let mut observers: HashMap<usize, Calibrator> = HashMap::new();
    let mut arena = fp32.new_arena();
    for b in 0..CALIBRATION_BATCHES {
        let inputs = synth_calibration_inputs(meta, index_bounds, 0x5eed + b as u64);
        fp32.execute_in(meta, &inputs, &mut arena, Some(&mut observers))?;
    }
    Ok(observers
        .into_iter()
        .map(|(i, cal)| (i, cal.l2_optimal_qparams(8, CALIBRATION_GRID)))
        .collect())
}

// ---------------------------------------------------------------------------
// Backend + artifact
// ---------------------------------------------------------------------------

/// Pure-Rust [`ExecBackend`] over the manifest op programs.
///
/// With a sparse tier attached ([`NativeBackend::with_sparse_tier`]),
/// `embed_pool` ops fetch pooled sums through the shared
/// [`EmbeddingShardService`] (registering each table on first load)
/// instead of holding a per-executor copy of every table — the §4
/// dis-aggregation of the sparse half of the model. `with_threads`
/// sets the intra-op GEMM fan-out (cores per op vs executors).
pub struct NativeBackend {
    precision: Precision,
    threads: usize,
    sparse: Option<Arc<EmbeddingShardService>>,
}

impl NativeBackend {
    pub fn new(precision: Precision) -> NativeBackend {
        NativeBackend { precision, threads: 1, sparse: None }
    }

    /// A backend whose pooled embedding lookups go through the shared
    /// sparse tier (int8 precisions register row-quantized slices).
    pub fn with_sparse_tier(
        precision: Precision,
        tier: Arc<EmbeddingShardService>,
    ) -> NativeBackend {
        NativeBackend { precision, threads: 1, sparse: Some(tier) }
    }

    /// Intra-op GEMM threads per FC/conv (0 = all available cores).
    pub fn with_threads(mut self, threads: usize) -> NativeBackend {
        self.threads = threads;
        self
    }

    /// [`ExecBackend::load`] returning the concrete artifact type, so
    /// callers (the allocation-ablation bench) can reach the
    /// arena-level execute entry points.
    pub fn load_native(&self, manifest: &Manifest, artifact: &str) -> Result<NativeArtifact> {
        let meta = manifest.artifact(artifact)?.clone();
        let wpath = manifest.weights_path(&meta);
        let named: Vec<NamedTensor> = match &wpath {
            Some(p) => read_weights_file(p)?,
            None => Vec::new(),
        };
        // Before any table enters the shared tier, hold the compiler's
        // per-table shard metadata to the actual table shapes: drift
        // between manifest and weights fails the load, not a lookup.
        if self.sparse.is_some() {
            if let Some(model) = &meta.model {
                validate_sparse_shard_meta(manifest, model, &named)
                    .with_context(|| format!("artifact {artifact}: sparse_shards metadata"))?;
            }
        }
        build_artifact(meta, &named, self.precision, self.sparse.clone(), self.threads)
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        format!("native-cpu (fbgemm-rs, {})", crate::gemm::detect_isa().as_str())
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn supported_precisions(&self) -> Vec<Precision> {
        Precision::all().to_vec()
    }

    fn load(&self, manifest: &Manifest, artifact: &str) -> Result<Box<dyn LoadedArtifact>> {
        Ok(Box::new(self.load_native(manifest, artifact)?))
    }
}

/// Validate the manifest's optional per-table `sparse_shards` row-range
/// metadata (emitted by `python/compile/aot.py`) against the weights
/// file: every listed table that exists must have ranges tiling exactly
/// `0..rows` ([`ShardPlan::from_json`]). Absent metadata is fine —
/// older manifests predate it.
fn validate_sparse_shard_meta(
    manifest: &Manifest,
    model: &str,
    named: &[NamedTensor],
) -> Result<()> {
    let Ok(cfg) = manifest.model_config(model) else {
        return Ok(()); // kernel artifacts have no model config
    };
    let shards = cfg.get("sparse_shards");
    if shards.is_null() {
        return Ok(());
    }
    let tables = shards.get("tables").as_obj().context("sparse_shards.tables must be an object")?;
    for (tname, ranges) in tables {
        let Some(t) = named.iter().find(|n| &n.name == tname) else {
            continue; // int8 variants carry a weight subset
        };
        ensure!(
            t.tensor.shape.len() == 2,
            "sparse_shards lists {tname}, which is not a 2-D table"
        );
        ShardPlan::from_json(ranges, t.tensor.shape[0])
            .with_context(|| format!("table {tname}"))?;
    }
    Ok(())
}

/// Compile one artifact's program at `precision` (weights already in
/// memory), planning the register arena and packing every layer with
/// `threads` intra-op GEMM workers. Split out of
/// [`NativeBackend::load`] so tests can build artifacts without a
/// manifest directory.
///
/// Calibration is deterministic, so every executor in a pool derives
/// identical qparams; each still packs/calibrates independently (same
/// per-thread-construction shape as the PJRT engine). Acceptable as
/// one-time startup cost at today's pool sizes — share the compiled
/// program via `Arc` if load time ever dominates.
pub(crate) fn build_artifact(
    meta: ArtifactMeta,
    named: &[NamedTensor],
    precision: Precision,
    sparse: Option<Arc<EmbeddingShardService>>,
    threads: usize,
) -> Result<NativeArtifact> {
    let t0 = Instant::now();
    let spec = parse_program(&meta.program)
        .with_context(|| format!("artifact {}: native program", meta.name))?;
    let weights: HashMap<String, &HostTensor> =
        named.iter().map(|t| (t.name.clone(), &t.tensor)).collect();
    // table keys are scoped by the weights file: batch variants of one
    // family share tier tables, distinct families never collide
    let scope = meta.weights.clone().unwrap_or_else(|| meta.name.clone());

    // smallest table each i32 input feeds, for calibration index synthesis
    let mut index_bounds: HashMap<String, usize> = HashMap::new();
    for op in &spec {
        if let OpSpec::EmbedPool { indices, table, .. } = op {
            let rows = weight(&weights, table)?.shape[0];
            let e = index_bounds.entry(indices.clone()).or_insert(rows);
            *e = (*e).min(rows);
        }
    }

    let program = match precision {
        Precision::Fp32 | Precision::Fp16 => CompiledProgram::build(
            &spec,
            &meta,
            &weights,
            precision,
            None,
            sparse.as_ref(),
            &scope,
            threads,
        )?,
        Precision::I8Acc32 | Precision::I8Acc16 => {
            // calibration runs on local fp32 tables: it must not pollute
            // the tier's cache or register throwaway fp32 copies
            let fp32 = CompiledProgram::build(
                &spec,
                &meta,
                &weights,
                Precision::Fp32,
                None,
                None,
                &scope,
                threads,
            )?;
            let qparams = calibrate(&fp32, &meta, &index_bounds)?;
            CompiledProgram::build(
                &spec,
                &meta,
                &weights,
                precision,
                Some(&qparams),
                sparse.as_ref(),
                &scope,
                threads,
            )?
        }
    };
    let plan = CompiledPlan::compile(&spec, &program, &meta);
    let arena = Mutex::new(program.new_arena());
    Ok(NativeArtifact {
        meta,
        program,
        plan,
        interpret: exec_interpret(),
        index_bounds,
        arena,
        load_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Build a native artifact directly from in-memory parts — the
/// differential-fuzzing / test entry: no manifest directory, no sparse
/// tier. Int8 precisions still calibrate through an internal fp32
/// build, exactly as [`NativeBackend::load`] does.
pub fn build_native_artifact(
    meta: ArtifactMeta,
    named: &[NamedTensor],
    precision: Precision,
    threads: usize,
) -> Result<NativeArtifact> {
    build_artifact(meta, named, precision, None, threads)
}

/// `DCINFER_EXEC=interpret` escape hatch, checked once per artifact
/// load: route execution through the op-by-op interpreter instead of
/// the compiled plan. The interpreter is the differential-fuzzing
/// oracle ([`NativeArtifact::run_interpreted`]); this flag flips whole
/// serving planes onto it without touching code.
fn exec_interpret() -> bool {
    std::env::var("DCINFER_EXEC").map(|v| v == "interpret").unwrap_or(false)
}

/// A compiled-and-packed native artifact with its persistent execution
/// arena (one per loaded artifact; executors own artifacts, so the
/// mutex is uncontended on the serving path).
pub struct NativeArtifact {
    meta: ArtifactMeta,
    program: CompiledProgram,
    /// Fused execution plan compiled at load time (the default path).
    plan: CompiledPlan,
    /// `DCINFER_EXEC=interpret` at load time: dispatch through the
    /// op-by-op interpreter instead of the plan.
    interpret: bool,
    /// Smallest table each i32 input feeds (for input synthesis).
    index_bounds: HashMap<String, usize>,
    arena: Mutex<ExecArena>,
    load_ms: f64,
}

impl NativeArtifact {
    /// A panicking batch must not permanently disable the artifact:
    /// recover the arena from a poisoned lock (buffer sizes are
    /// plan-fixed, so the state stays structurally valid; a batch that
    /// panicked mid-op surfaces again per-request, not as a poisoned
    /// `unwrap` forever).
    fn lock_arena(&self) -> std::sync::MutexGuard<'_, ExecArena> {
        self.arena.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Execute into the persistent arena without materializing output
    /// tensors: the zero-steady-state-allocation hot path that
    /// [`LoadedArtifact::run`] wraps. `ablation_alloc` measures this
    /// entry point with a counting allocator. Runs the compiled plan
    /// unless the artifact was loaded under `DCINFER_EXEC=interpret`.
    pub fn execute_steady(&self, inputs: &[HostTensor]) -> Result<()> {
        let mut arena = self.lock_arena();
        if self.interpret {
            self.program.execute_in(&self.meta, inputs, &mut arena, None)
        } else {
            self.plan.execute(&self.program, &self.meta, inputs, &mut arena)
        }
    }

    /// Execute with a freshly allocated arena, discarded afterwards —
    /// the pre-arena allocate-per-batch behavior, kept as the ablation
    /// baseline (`ablation_alloc` compares it against
    /// [`NativeArtifact::execute_steady`]).
    pub fn execute_fresh(&self, inputs: &[HostTensor]) -> Result<()> {
        let mut arena = self.program.new_arena();
        self.program.execute_in(&self.meta, inputs, &mut arena, None)
    }

    /// What the plan compiler fused at load time (per-chain signatures
    /// and roofline estimates) — the §3.3 mining pass reported against
    /// this artifact's op program.
    pub fn fusion_report(&self) -> &FusionReport {
        self.plan.report()
    }

    /// Run through the compiled plan explicitly, regardless of the
    /// `DCINFER_EXEC` mode the artifact was loaded under.
    pub fn run_compiled(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let mut arena = self.lock_arena();
        self.plan.execute(&self.program, &self.meta, inputs, &mut arena)?;
        Ok(self.materialize(&arena))
    }

    /// Run through the op-by-op interpreter explicitly — the
    /// differential-fuzzing oracle the compiled plan is sealed against.
    pub fn run_interpreted(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let mut arena = self.lock_arena();
        self.program.execute_in(&self.meta, inputs, &mut arena, None)?;
        Ok(self.materialize(&arena))
    }

    /// Deterministic synthetic inputs matching the artifact's input
    /// metas (i32 index inputs draw below the smallest table they
    /// feed) — what calibration uses, exposed for benches and fuzzers.
    pub fn synth_inputs(&self, seed: u64) -> Vec<HostTensor> {
        synth_calibration_inputs(&self.meta, &self.index_bounds, seed)
    }

    fn materialize(&self, arena: &ExecArena) -> Vec<HostTensor> {
        let mut outs = Vec::with_capacity(self.meta.outputs.len());
        for (om, src) in self.meta.outputs.iter().zip(&self.program.plan.output_src) {
            outs.push(HostTensor::from_f32(&om.shape, &arena.bufs[*src]));
        }
        outs
    }
}

impl LoadedArtifact for NativeArtifact {
    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let mut arena = self.lock_arena();
        if self.interpret {
            self.program.execute_in(&self.meta, inputs, &mut arena, None)?;
        } else {
            self.plan.execute(&self.program, &self.meta, inputs, &mut arena)?;
        }
        Ok(self.materialize(&arena))
    }

    fn load_ms(&self) -> f64 {
        self.load_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::sqnr_db;
    use crate::runtime::manifest::TensorMeta;

    fn named(name: &str, shape: &[usize], data: Vec<f32>) -> NamedTensor {
        NamedTensor { name: name.to_string(), tensor: HostTensor::from_f32(shape, &data) }
    }

    fn meta_with(
        inputs: Vec<TensorMeta>,
        outputs: Vec<TensorMeta>,
        batch: usize,
        program: &str,
    ) -> ArtifactMeta {
        ArtifactMeta {
            name: "t".into(),
            hlo: "t.hlo.txt".into(),
            model: None,
            weights: Some("t.weights.bin".into()),
            weight_params: vec![],
            inputs,
            outputs,
            batch,
            precision: Precision::Fp32,
            program: Json::parse(program).unwrap(),
        }
    }

    fn tm(name: &str, dtype: DType, shape: &[usize]) -> TensorMeta {
        TensorMeta { name: name.into(), dtype, shape: shape.to_vec() }
    }

    #[test]
    fn fc_chain_matches_hand_math() {
        // y = sigmoid(relu(x @ W0^T + b0) @ W1^T)
        let w0 = vec![1.0, 0.0, 0.0, -1.0]; // [2x2] identity-ish
        let b0 = vec![0.5, 0.5];
        let w1 = vec![1.0, 1.0]; // [1x2]
        let prog = r#"[
            {"op": "fc", "out": "h", "in": "x", "w": "w0", "b": "b0", "act": "relu"},
            {"op": "fc", "out": "l", "in": "h", "w": "w1", "act": "none"},
            {"op": "unary", "fn": "sigmoid", "out": "y", "in": "l"}
        ]"#;
        let meta = meta_with(
            vec![tm("x", DType::F32, &[1, 2])],
            vec![tm("y", DType::F32, &[1, 1])],
            1,
            prog,
        );
        let ws = vec![
            named("w0", &[2, 2], w0),
            named("b0", &[2], b0),
            named("w1", &[1, 2], w1),
        ];
        let art = build_artifact(meta, &ws, Precision::Fp32, None, 1).unwrap();
        let out = art.run(&[HostTensor::from_f32(&[1, 2], &[2.0, 3.0])]).unwrap();
        // h = relu([2 + .5, -3 + .5]) = [2.5, 0]; l = 2.5; y = sigmoid(2.5)
        let want = 1.0 / (1.0 + (-2.5f32).exp());
        let got = out[0].as_f32().unwrap()[0];
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn gru_style_elementwise_ops() {
        // h_new = (1 - z) * h + z * hh with z, h, hh as inputs
        let prog = r#"[
            {"op": "unary", "fn": "one_minus", "out": "omz", "in": "z"},
            {"op": "binary", "fn": "mul", "out": "a", "a": "omz", "b": "h"},
            {"op": "binary", "fn": "mul", "out": "b2", "a": "z", "b": "hh"},
            {"op": "binary", "fn": "add", "out": "h_new", "a": "a", "b": "b2"}
        ]"#;
        let meta = meta_with(
            vec![
                tm("z", DType::F32, &[1, 2]),
                tm("h", DType::F32, &[1, 2]),
                tm("hh", DType::F32, &[1, 2]),
            ],
            vec![tm("h_new", DType::F32, &[1, 2])],
            1,
            prog,
        );
        let art = build_artifact(meta, &[], Precision::Fp32, None, 1).unwrap();
        let out = art
            .run(&[
                HostTensor::from_f32(&[1, 2], &[0.25, 1.0]),
                HostTensor::from_f32(&[1, 2], &[4.0, 4.0]),
                HostTensor::from_f32(&[1, 2], &[8.0, 8.0]),
            ])
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), vec![5.0, 8.0]);
    }

    #[test]
    fn embed_pool_slices_and_sums() {
        // 2 tables of 4 rows x 2 dims; indices [B=1, T=2, P=2]
        let t0: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let t1: Vec<f32> = (0..8).map(|v| (10 + v) as f32).collect();
        let prog = r#"[
            {"op": "embed_pool", "out": "p0", "indices": "idx", "table": "e0", "slice": 0},
            {"op": "embed_pool", "out": "p1", "indices": "idx", "table": "e1", "slice": 1},
            {"op": "concat", "out": "z", "in": ["p0", "p1"]}
        ]"#;
        let meta = meta_with(
            vec![tm("idx", DType::I32, &[1, 2, 2])],
            vec![tm("z", DType::F32, &[1, 4])],
            1,
            prog,
        );
        let ws = vec![named("e0", &[4, 2], t0), named("e1", &[4, 2], t1)];
        let art = build_artifact(meta, &ws, Precision::Fp32, None, 1).unwrap();
        // table 0 pools rows {0, 1} -> [0+2, 1+3]; table 1 rows {2, 3} -> [14+16, 15+17]
        let out = art.run(&[HostTensor::from_i32(&[1, 2, 2], &[0, 1, 2, 3])]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), vec![2.0, 4.0, 30.0, 32.0]);
    }

    #[test]
    fn embed_pool_rejects_out_of_range_index() {
        let prog = r#"[{"op": "embed_pool", "out": "p", "indices": "idx", "table": "e0"}]"#;
        let meta = meta_with(
            vec![tm("idx", DType::I32, &[1, 2])],
            vec![tm("p", DType::F32, &[1, 2])],
            1,
            prog,
        );
        let ws = vec![named("e0", &[4, 2], vec![0.0; 8])];
        let art = build_artifact(meta, &ws, Precision::Fp32, None, 1).unwrap();
        assert!(art.run(&[HostTensor::from_i32(&[1, 2], &[0, 4])]).is_err());
        assert!(art.run(&[HostTensor::from_i32(&[1, 2], &[-1, 0])]).is_err());
        // a failed batch must not poison the arena for the next one
        let ok = art.run(&[HostTensor::from_i32(&[1, 2], &[0, 1])]).unwrap();
        assert_eq!(ok[0].as_f32().unwrap(), vec![0.0, 0.0]);
    }

    #[test]
    fn conv2d_matches_naive_reference() {
        let mut rng = Pcg32::seeded(3);
        let (b, c, h, w, co, k, stride) = (2usize, 3usize, 6usize, 6usize, 4usize, 3usize, 2usize);
        // SAME for stride 2, k 3, h 6: ho=3, total pad = (3-1)*2+3-6 = 1 -> (0,1)
        let (plo, phi) = (0usize, 1usize);
        let ho = (h + plo + phi - k) / stride + 1;
        let x: Vec<f32> = (0..b * c * h * w).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let wt: Vec<f32> = (0..co * c * k * k).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let bias: Vec<f32> = (0..co).map(|i| i as f32 * 0.1).collect();

        let prog = format!(
            r#"[{{"op": "conv2d", "out": "y", "in": "x", "w": "cw", "b": "cb",
                 "act": "relu", "stride": {stride}, "pad": [{plo}, {phi}]}}]"#
        );
        let meta = meta_with(
            vec![tm("x", DType::F32, &[b, c, h, w])],
            vec![tm("y", DType::F32, &[b, co, ho, ho])],
            b,
            &prog,
        );
        let ws = vec![named("cw", &[co, c, k, k], wt.clone()), named("cb", &[co], bias.clone())];
        let art = build_artifact(meta, &ws, Precision::Fp32, None, 1).unwrap();
        let got = art.run(&[HostTensor::from_f32(&[b, c, h, w], &x)]).unwrap()[0]
            .as_f32()
            .unwrap();

        // naive reference
        for bi in 0..b {
            for o in 0..co {
                for y in 0..ho {
                    for xx in 0..ho {
                        let mut acc = bias[o];
                        for ci in 0..c {
                            for ky in 0..k {
                                for kx in 0..k {
                                    let iy = (y * stride + ky) as isize - plo as isize;
                                    let ix = (xx * stride + kx) as isize - plo as isize;
                                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w
                                    {
                                        acc += x[((bi * c + ci) * h + iy as usize) * w
                                            + ix as usize]
                                            * wt[((o * c + ci) * k + ky) * k + kx];
                                    }
                                }
                            }
                        }
                        let want = acc.max(0.0);
                        let gotv = got[((bi * co + o) * ho + y) * ho + xx];
                        assert!((gotv - want).abs() < 1e-4, "{gotv} vs {want}");
                    }
                }
            }
        }
    }

    #[test]
    fn parse_rejects_unknown_ops() {
        assert!(parse_program(&Json::parse(r#"[{"op": "nope", "out": "x"}]"#).unwrap()).is_err());
        assert!(parse_program(&Json::parse("[]").unwrap()).is_err());
        assert!(parse_program(&Json::Null).is_err());
    }

    fn tiny_mlp_artifact(precision: Precision) -> (NativeArtifact, Vec<HostTensor>) {
        let mut rng = Pcg32::seeded(7);
        let (din, dh, dout) = (8usize, 16usize, 4usize);
        let w0: Vec<f32> = (0..dh * din).map(|_| rng.normal_f32(0.0, 0.4)).collect();
        let b0: Vec<f32> = (0..dh).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let w1: Vec<f32> = (0..dout * dh).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let prog = r#"[
            {"op": "fc", "out": "h", "in": "x", "w": "w0", "b": "b0", "act": "relu"},
            {"op": "fc", "out": "y", "in": "h", "w": "w1", "act": "none"}
        ]"#;
        let meta = meta_with(
            vec![tm("x", DType::F32, &[4, din])],
            vec![tm("y", DType::F32, &[4, dout])],
            4,
            prog,
        );
        let ws = vec![
            named("w0", &[dh, din], w0),
            named("b0", &[dh], b0),
            named("w1", &[dout, dh], w1),
        ];
        let art = build_artifact(meta, &ws, precision, None, 1).unwrap();
        let mut x = vec![0f32; 4 * din];
        let mut rng = Pcg32::seeded(99);
        rng.fill_normal(&mut x, 0.0, 1.0);
        (art, vec![HostTensor::from_f32(&[4, din], &x)])
    }

    #[test]
    fn reduced_precisions_track_fp32_within_bounds() {
        let (ref_art, inputs) = tiny_mlp_artifact(Precision::Fp32);
        let reference = ref_art.run(&inputs).unwrap()[0].as_f32().unwrap();
        for p in [Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16] {
            let (art, _) = tiny_mlp_artifact(p);
            let got = art.run(&inputs).unwrap()[0].as_f32().unwrap();
            let db = sqnr_db(&reference, &got);
            assert!(db >= p.min_sqnr_db(), "{p}: sqnr {db:.1} dB < {}", p.min_sqnr_db());
        }
    }

    #[test]
    fn arena_reuse_is_stateless_across_batches() {
        let (art, inputs) = tiny_mlp_artifact(Precision::Fp32);
        let first = art.run(&inputs).unwrap()[0].as_f32().unwrap();
        // interleave a different batch, then re-run the original: the
        // reused arena must not leak state between batches
        let mut rng = Pcg32::seeded(1234);
        let mut other = vec![0f32; 4 * 8];
        rng.fill_normal(&mut other, 0.0, 2.0);
        let _ = art.run(&[HostTensor::from_f32(&[4, 8], &other)]).unwrap();
        let again = art.run(&inputs).unwrap()[0].as_f32().unwrap();
        assert_eq!(first, again);
    }

    #[test]
    fn steady_and_fresh_execute_paths_agree_with_run() {
        let (art, inputs) = tiny_mlp_artifact(Precision::Fp32);
        let want = art.run(&inputs).unwrap()[0].as_f32().unwrap();
        art.execute_steady(&inputs).unwrap();
        art.execute_fresh(&inputs).unwrap();
        let got = art.run(&inputs).unwrap()[0].as_f32().unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn threaded_build_matches_serial_bitwise() {
        let (serial, inputs) = tiny_mlp_artifact(Precision::Fp32);
        let want = serial.run(&inputs).unwrap()[0].as_f32().unwrap();
        // rebuild the same artifact with intra-op threads
        let mut rng = Pcg32::seeded(7);
        let (din, dh, dout) = (8usize, 16usize, 4usize);
        let w0: Vec<f32> = (0..dh * din).map(|_| rng.normal_f32(0.0, 0.4)).collect();
        let b0: Vec<f32> = (0..dh).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let w1: Vec<f32> = (0..dout * dh).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let prog = r#"[
            {"op": "fc", "out": "h", "in": "x", "w": "w0", "b": "b0", "act": "relu"},
            {"op": "fc", "out": "y", "in": "h", "w": "w1", "act": "none"}
        ]"#;
        let meta = meta_with(
            vec![tm("x", DType::F32, &[4, din])],
            vec![tm("y", DType::F32, &[4, dout])],
            4,
            prog,
        );
        let ws = vec![
            named("w0", &[dh, din], w0),
            named("b0", &[dh], b0),
            named("w1", &[dout, dh], w1),
        ];
        let art = build_artifact(meta, &ws, Precision::Fp32, None, 3).unwrap();
        let got = art.run(&inputs).unwrap()[0].as_f32().unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn flatten_aliases_and_inplace_unary_share_buffers() {
        // y = sigmoid(flatten(x) @ W^T): flatten is a view; sigmoid's
        // input (the fc result) dies at the unary, so the output
        // aliases it. Correctness over two batches seals both.
        let w: Vec<f32> = (0..2 * 6).map(|v| (v as f32) * 0.1 - 0.5).collect();
        let prog = r#"[
            {"op": "flatten", "out": "f", "in": "x"},
            {"op": "fc", "out": "l", "in": "f", "w": "w", "act": "none"},
            {"op": "unary", "fn": "sigmoid", "out": "y", "in": "l"}
        ]"#;
        let meta = meta_with(
            vec![tm("x", DType::F32, &[1, 2, 3])],
            vec![tm("y", DType::F32, &[1, 2])],
            1,
            prog,
        );
        let ws = vec![named("w", &[2, 6], w.clone())];
        let art = build_artifact(meta, &ws, Precision::Fp32, None, 1).unwrap();
        for seed in [5u64, 6] {
            let mut rng = Pcg32::seeded(seed);
            let mut x = vec![0f32; 6];
            rng.fill_normal(&mut x, 0.0, 1.0);
            let got = art.run(&[HostTensor::from_f32(&[1, 2, 3], &x)]).unwrap()[0]
                .as_f32()
                .unwrap();
            for (j, g) in got.iter().enumerate() {
                let mut s = 0f32;
                for kk in 0..6 {
                    s += x[kk] * w[j * 6 + kk];
                }
                let want = 1.0 / (1.0 + (-s).exp());
                assert!((g - want).abs() < 1e-5, "seed {seed} col {j}: {g} vs {want}");
            }
        }
    }

    #[test]
    fn fc_layer_precisions_agree_on_random_gemm() {
        let mut rng = Pcg32::seeded(21);
        let (m, n, k) = (8usize, 32usize, 64usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let w: Vec<f32> = (0..n * k).map(|_| rng.normal_f32(0.0, 0.2)).collect();
        let (lo, hi) = a.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let x_qp = QParams::from_range(lo, hi, 8, false);
        let mut reference = vec![0f32; m * n];
        FcLayer::from_f32(Precision::Fp32, &w, n, k, None, false, x_qp)
            .forward(&a, m, &mut reference);
        for p in [Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16] {
            let layer = FcLayer::from_f32(p, &w, n, k, None, false, x_qp);
            assert_eq!(layer.precision(), p);
            let mut c = vec![0f32; m * n];
            layer.forward(&a, m, &mut c);
            let db = sqnr_db(&reference, &c);
            assert!(db >= p.min_sqnr_db(), "{p}: sqnr {db:.1} dB");
        }
    }

    #[test]
    fn acc16_ablation_constructor_gets_denser_outliers_at_fewer_bits() {
        let mut rng = Pcg32::seeded(31);
        let (n, k) = (32usize, 64usize);
        let wq: Vec<i8> =
            (0..n * k).map(|_| rng.normal_f32(0.0, 24.0).round().clamp(-127.0, 127.0) as i8).collect();
        let qp = QParams::from_range(-1.0, 1.0, 8, false);
        let d7 = FcLayer::i8acc16_from_quantized(&wq, n, k, 7, qp, 0.01, None, false)
            .outlier_density()
            .unwrap();
        let d4 = FcLayer::i8acc16_from_quantized(&wq, n, k, 4, qp, 0.01, None, false)
            .outlier_density()
            .unwrap();
        assert!(d4 > d7, "d4 {d4} d7 {d7}");
    }
}
