//! Compiled execution plans: the op program lowered, at artifact load
//! time, into a flat step table with fused GEMM epilogues (§3.2.3 /
//! §3.3).
//!
//! The interpreter ([`super::native`]) walks the compiled op list and
//! re-dispatches every op per batch; trailing elementwise ops
//! (`relu`/`sigmoid`/`tanh`/`one_minus`, `add`/`mul`) each make a full
//! extra pass over the producer's output buffer. The plan compiler
//! removes both costs:
//!
//! - **Epilogue folding.** Chains mined from the op program
//!   ([`crate::graph::miner::mine_program_chains`] — the §3.3
//!   fusion-discovery pass, retargeted from the seed-era NetDef path
//!   onto real artifact programs) are folded into the producer's GEMM
//!   [`crate::gemm::Epilogue`]: each output element runs the whole
//!   `fc -> unary -> binary` tail at kernel write-out, and the chain's
//!   intermediate buffers are never materialized.
//! - **Pre-resolved dispatch.** Every surviving op becomes one
//!   `PlanStep`: a direct function pointer plus slot indices resolved
//!   at compile time. Batch execution is a linear walk of the step
//!   table — no name lookups, no per-op precision/ISA decisions.
//!
//! **Numerics contract.** Folding must be bit-identical to the
//! interpreter at fp32/fp16: a folded tail applies exactly the same
//! scalar functions, in the same op order, to exactly the same
//! pipeline output value each element saw before — and GEMM
//! accumulation order (k-ascending) is untouched, so fusion never
//! changes summation order. Binary operand order is preserved through
//! [`TailOp`]'s `swapped` flag (float add/mul are commutative except
//! for NaN payload propagation, which we keep identical anyway). The
//! differential fuzzer (`tests/plan_differential.rs`) seals this
//! contract against the interpreter oracle, reachable at serving scope
//! via the `DCINFER_EXEC=interpret` escape hatch.
//!
//! Fusion refusal rules (conservative, enforced at mine + lower time):
//! chain members must immediately follow their producer; every chain
//! intermediate must have exactly one consumer and must not be an
//! artifact output; a binary folds only when exactly one operand is
//! the chain value and the other predates the producer; conv chains
//! fold unaries only (the NCHW scatter would remap a binary operand's
//! indexing); tails are capped at [`MAX_TAIL`] ops.

use std::collections::{HashMap, HashSet};
use std::mem;

use anyhow::Result;

use crate::gemm::TailOp;
use crate::graph::fusion::fusion_speedup;
use crate::graph::miner::{mine_program_chains, ChainKind, MinedSubgraph, ProgramOp};
use crate::graph::netdef::{Net, Node};
use crate::models::OpClass;
use crate::perfmodel::DeviceSpec;

use super::manifest::ArtifactMeta;
use super::native::{
    im2col, nchw_scatter, BinaryFn, CompiledOp, CompiledProgram, ExecArena, OpSpec, UnaryFn,
};
use super::tensor::HostTensor;

/// Epilogue tail capacity: the producer's own folded activation plus up
/// to `MAX_TAIL - 1` mined chain members, applied from a fixed-size
/// stack array so plan execution stays allocation-free.
pub const MAX_TAIL: usize = 4;

/// One folded tail op with its operands resolved to arena slots; bound
/// to borrowed buffers ([`TailOp`]) at execution time.
#[derive(Debug, Clone)]
pub(crate) enum TailSpec {
    /// Elementwise unary folded into the epilogue.
    Unary(UnaryFn),
    /// Elementwise binary: `operand` is the canonical arena slot of the
    /// non-chain side; `swapped` records that the chain value was the
    /// *right* operand, preserving the interpreter's operand order.
    Binary { f: BinaryFn, operand: usize, swapped: bool },
}

impl TailSpec {
    /// Bind to the arena's buffers for one batch.
    #[inline(always)]
    fn bind<'a>(&self, bufs: &'a [Vec<f32>]) -> TailOp<'a> {
        match self {
            TailSpec::Unary(f) => unary_tail(*f),
            TailSpec::Binary { f, operand, swapped } => {
                let xs = bufs[*operand].as_slice();
                match f {
                    BinaryFn::Add => TailOp::Add { operand: xs, swapped: *swapped },
                    BinaryFn::Mul => TailOp::Mul { operand: xs, swapped: *swapped },
                }
            }
        }
    }
}

fn unary_tail(f: UnaryFn) -> TailOp<'static> {
    match f {
        UnaryFn::Relu => TailOp::Relu,
        UnaryFn::Sigmoid => TailOp::Sigmoid,
        UnaryFn::Tanh => TailOp::Tanh,
        UnaryFn::OneMinus => TailOp::OneMinus,
    }
}

/// Pre-resolved arguments of one plan step.
#[derive(Debug, Clone)]
pub(crate) enum StepArgs {
    /// fc producer; `out` is the canonical slot the (possibly fused)
    /// chain writes and `tail` is empty for unfused layers.
    Fc { op: usize, out: usize, tail: Vec<TailSpec> },
    /// conv2d producer (unary-only tails; applied pre-scatter).
    Conv { op: usize, out: usize, tail: Vec<TailSpec> },
    /// Pass-through op executed via the shared interpreter body.
    Op { op: usize },
}

type StepFn = fn(&StepArgs, &CompiledProgram, &mut ExecArena) -> Result<()>;

/// One step of a compiled plan: a direct function pointer plus its
/// pre-resolved arguments. Dispatch is `(step.run)(..)` — no op-kind
/// match, no name resolution, no per-batch decisions.
pub(crate) struct PlanStep {
    run: StepFn,
    args: StepArgs,
}

/// One fused chain in a [`FusionReport`].
#[derive(Debug, Clone)]
pub struct FusedChain {
    /// NetDef-style bucket signature, e.g. `FC>Elementwise>Elementwise`.
    pub signature: String,
    /// Chain members folded into the producer's epilogue.
    pub folded: usize,
    /// Roofline speedup estimate ([`crate::graph::fusion`]) for this
    /// chain on the serving CPU — the §3.3 ranking model applied to a
    /// chain we actually fused.
    pub est_speedup: f64,
}

/// What the plan compiler did to one artifact's op program.
#[derive(Debug, Clone)]
pub struct FusionReport {
    /// Artifact the plan was compiled for.
    pub artifact: String,
    /// Compiled ops before folding (what the interpreter executes).
    pub interp_ops: usize,
    /// Steps in the compiled plan (after folding).
    pub plan_steps: usize,
    /// fc/conv activations (sigmoid/tanh) folded from a separate
    /// interpreter pass into the GEMM epilogue.
    pub folded_activations: usize,
    /// Mined chains folded into producer epilogues.
    pub chains: Vec<FusedChain>,
}

impl FusionReport {
    /// One-line human summary for benches and logs.
    pub fn summary(&self) -> String {
        if self.chains.is_empty() {
            return format!(
                "{}: {} ops -> {} steps, no fused chains",
                self.artifact, self.interp_ops, self.plan_steps
            );
        }
        let parts: Vec<String> = self
            .chains
            .iter()
            .map(|c| format!("{} (+{} ops, est x{:.2})", c.signature, c.folded, c.est_speedup))
            .collect();
        format!(
            "{}: {} ops -> {} steps; fused {}",
            self.artifact,
            self.interp_ops,
            self.plan_steps,
            parts.join(", ")
        )
    }
}

/// A compiled execution plan: the op program with fusable chains folded
/// into GEMM epilogues and all dispatch pre-resolved into a flat step
/// table. Compiled once per artifact load; executed per batch with
/// zero heap allocations and zero per-op decisions.
pub struct CompiledPlan {
    steps: Vec<PlanStep>,
    report: FusionReport,
}

/// Internal: one lowered (validated) chain.
struct Lowered {
    /// Compiled-op index of the producer.
    producer: usize,
    /// Canonical slot the fused step writes (the chain's final output).
    out: usize,
    tail: Vec<TailSpec>,
    /// Compiled-op indices of the folded members, in chain order.
    members: Vec<usize>,
}

impl CompiledPlan {
    /// Lower `prog` into a step table, folding every chain
    /// [`mine_program_chains`] finds in `spec` that survives slot-level
    /// validation. Never fails: any chain that cannot be proven safe is
    /// simply left unfused.
    pub(crate) fn compile(
        spec: &[OpSpec],
        prog: &CompiledProgram,
        meta: &ArtifactMeta,
    ) -> CompiledPlan {
        // spec index -> compiled-op index (flatten compiles away)
        let mut op_of: Vec<Option<usize>> = Vec::with_capacity(spec.len());
        let mut next = 0usize;
        for s in spec {
            if matches!(s, OpSpec::Flatten { .. }) {
                op_of.push(None);
            } else {
                op_of.push(Some(next));
                next += 1;
            }
        }
        let aligned = next == prog.ops.len();
        debug_assert!(aligned, "spec/compiled op count drift");

        let mined = if aligned {
            let view = program_view(spec);
            let outputs: Vec<String> = meta.outputs.iter().map(|o| o.name.clone()).collect();
            mine_program_chains(&view, &outputs, MAX_TAIL - 1)
        } else {
            Vec::new()
        };

        // --- lower mined chains to slot-level tails -------------------
        let mut lowered: Vec<Lowered> = Vec::new();
        'chains: for ch in &mined {
            let Some(pidx) = op_of[ch.producer] else { continue };
            let mut chain_slot = match &prog.ops[pidx] {
                CompiledOp::Fc { out, .. } | CompiledOp::Conv2d { out, .. } => *out,
                _ => continue,
            };
            let mut tail = Vec::with_capacity(ch.members.len());
            let mut members = Vec::with_capacity(ch.members.len());
            for &ms in &ch.members {
                let Some(mi) = op_of[ms] else { continue 'chains };
                match &prog.ops[mi] {
                    CompiledOp::Unary { out, f, .. } => {
                        tail.push(TailSpec::Unary(*f));
                        chain_slot = *out; // == chain_slot when in place
                    }
                    CompiledOp::Binary { out, a, b, f } => {
                        // exactly one operand must be the chain value
                        let (operand, swapped) = if *a == chain_slot && *b != chain_slot {
                            (*b, false)
                        } else if *b == chain_slot && *a != chain_slot {
                            (*a, true)
                        } else {
                            continue 'chains; // slot-level ambiguity: refuse
                        };
                        tail.push(TailSpec::Binary { f: *f, operand, swapped });
                        chain_slot = *out;
                    }
                    _ => continue 'chains,
                }
                members.push(mi);
            }
            if !members.is_empty() {
                lowered.push(Lowered { producer: pidx, out: chain_slot, tail, members });
            }
        }

        // --- emit the step table --------------------------------------
        let fused_at: HashMap<usize, usize> =
            lowered.iter().enumerate().map(|(ci, l)| (l.producer, ci)).collect();
        let member_of: HashSet<usize> =
            lowered.iter().flat_map(|l| l.members.iter().copied()).collect();
        let mut steps: Vec<PlanStep> = Vec::new();
        let mut folded_activations = 0usize;
        for (i, op) in prog.ops.iter().enumerate() {
            if member_of.contains(&i) {
                continue;
            }
            let (fused_out, tail) = match fused_at.get(&i) {
                Some(&ci) => (Some(lowered[ci].out), lowered[ci].tail.clone()),
                None => (None, Vec::new()),
            };
            let step = match op {
                CompiledOp::Fc { out, post, .. } => {
                    folded_activations += post.is_some() as usize;
                    PlanStep {
                        run: run_fc,
                        args: StepArgs::Fc { op: i, out: fused_out.unwrap_or(*out), tail },
                    }
                }
                CompiledOp::Conv2d { out, post, .. } => {
                    folded_activations += post.is_some() as usize;
                    PlanStep {
                        run: run_conv,
                        args: StepArgs::Conv { op: i, out: fused_out.unwrap_or(*out), tail },
                    }
                }
                CompiledOp::EmbedPool { .. } => {
                    PlanStep { run: run_embed, args: StepArgs::Op { op: i } }
                }
                CompiledOp::Concat { .. } => {
                    PlanStep { run: run_concat, args: StepArgs::Op { op: i } }
                }
                CompiledOp::Unary { .. } => {
                    PlanStep { run: run_unary, args: StepArgs::Op { op: i } }
                }
                CompiledOp::Binary { .. } => {
                    PlanStep { run: run_binary, args: StepArgs::Op { op: i } }
                }
            };
            steps.push(step);
        }

        let chains = lowered.iter().map(|l| chain_report(l, prog, meta)).collect();
        let report = FusionReport {
            artifact: meta.name.clone(),
            interp_ops: prog.ops.len(),
            plan_steps: steps.len(),
            folded_activations,
            chains,
        };
        CompiledPlan { steps, report }
    }

    /// Execute one batch through the step table into `arena`. Zero heap
    /// allocations once the arena is warm — tails bind to borrowed
    /// buffers through a fixed-size stack array.
    pub(crate) fn execute(
        &self,
        prog: &CompiledProgram,
        meta: &ArtifactMeta,
        inputs: &[HostTensor],
        arena: &mut ExecArena,
    ) -> Result<()> {
        prog.decode_inputs(meta, inputs, arena)?;
        for step in &self.steps {
            (step.run)(&step.args, prog, arena)?;
        }
        Ok(())
    }

    /// What the compiler fused (and an estimate of what it bought).
    pub fn report(&self) -> &FusionReport {
        &self.report
    }
}

/// Reduce the parsed spec to the miner's program view: who writes what,
/// who reads what, and which ops can host or join an epilogue chain.
fn program_view(spec: &[OpSpec]) -> Vec<ProgramOp> {
    spec.iter()
        .map(|op| match op {
            OpSpec::Fc { out, input, .. } => ProgramOp {
                kind: ChainKind::Gemm,
                out: out.clone(),
                reads: vec![input.clone()],
            },
            OpSpec::Conv2d { out, input, .. } => ProgramOp {
                kind: ChainKind::GemmScattered,
                out: out.clone(),
                reads: vec![input.clone()],
            },
            // indices are i32 side inputs, not foldable f32 values
            OpSpec::EmbedPool { out, .. } => {
                ProgramOp { kind: ChainKind::Opaque, out: out.clone(), reads: Vec::new() }
            }
            OpSpec::Concat { out, inputs } => {
                ProgramOp { kind: ChainKind::Opaque, out: out.clone(), reads: inputs.clone() }
            }
            OpSpec::Unary { out, input, .. } => ProgramOp {
                kind: ChainKind::Unary,
                out: out.clone(),
                reads: vec![input.clone()],
            },
            OpSpec::Binary { out, a, b, .. } => ProgramOp {
                kind: ChainKind::Binary,
                out: out.clone(),
                reads: vec![a.clone(), b.clone()],
            },
            OpSpec::Flatten { out, input } => ProgramOp {
                kind: ChainKind::Opaque,
                out: out.clone(),
                reads: vec![input.clone()],
            },
        })
        .collect()
}

/// Build the per-chain report entry: a NetDef signature plus the §3.3
/// roofline speedup estimate, via the revived [`crate::graph`] pass.
fn chain_report(l: &Lowered, prog: &CompiledProgram, meta: &ArtifactMeta) -> FusedChain {
    let slot_bytes = |s: usize| (prog.plan.slots[s].len * 4) as u64;
    let (mut nodes, mut classes): (Vec<Node>, Vec<OpClass>) = (Vec::new(), Vec::new());
    let push = |nodes: &mut Vec<Node>, classes: &mut Vec<OpClass>, cls, flops, bin, bout| {
        let i = nodes.len();
        nodes.push(Node {
            op: cls,
            name: format!("n{i}"),
            flops,
            bytes_in: bin,
            bytes_out: bout,
            inputs: if i == 0 { vec![] } else { vec![i - 1] },
        });
        classes.push(cls);
    };
    match &prog.ops[l.producer] {
        CompiledOp::Fc { out, input, m, layer, .. } => {
            let wb = weight_bytes_per_elem(meta.precision);
            let flops = (2 * m * layer.n * layer.k) as u64;
            let bin = slot_bytes(*input) + (layer.n * layer.k) as u64 * wb;
            push(&mut nodes, &mut classes, OpClass::Fc, flops, bin, slot_bytes(*out));
        }
        CompiledOp::Conv2d { out, input, layer, geom, .. } => {
            let wb = weight_bytes_per_elem(meta.precision);
            let flops = (2 * geom.rows * layer.n * layer.k) as u64;
            let bin = slot_bytes(*input) + (layer.n * layer.k) as u64 * wb;
            push(&mut nodes, &mut classes, OpClass::Conv, flops, bin, slot_bytes(*out));
        }
        _ => {}
    }
    let mut extra_operand_bytes = 0u64;
    for &mi in &l.members {
        match &prog.ops[mi] {
            CompiledOp::Unary { out, .. } => {
                let b = slot_bytes(*out);
                push(&mut nodes, &mut classes, OpClass::Elementwise, b / 4, b, b);
            }
            CompiledOp::Binary { out, a, b, .. } => {
                let bo = slot_bytes(*out);
                let operand = slot_bytes(*a).min(slot_bytes(*b));
                extra_operand_bytes += operand;
                push(&mut nodes, &mut classes, OpClass::Elementwise, bo / 4, 2 * bo, bo);
            }
            _ => {}
        }
    }
    let net = Net { name: meta.name.clone(), nodes };
    let idx: Vec<usize> = (0..net.nodes.len()).collect();
    let signature = net.chain_signature(&idx);
    let intermediate: u64 =
        net.nodes[..net.nodes.len().saturating_sub(1)].iter().map(|n| n.bytes_out).sum();
    let sub = MinedSubgraph {
        signature: signature.clone(),
        ops: classes,
        frequency: 1.0,
        avg_flops: net.nodes.iter().map(|n| n.flops).sum::<u64>() as f64,
        avg_bytes_in: (net.nodes[0].bytes_in + extra_operand_bytes) as f64,
        avg_bytes_out: net.nodes.last().map(|n| n.bytes_out).unwrap_or(0) as f64,
        avg_intermediate_bytes: intermediate as f64,
    };
    let (t_unfused, t_fused) = fusion_speedup(&sub, &DeviceSpec::xeon_fp32());
    FusedChain {
        signature,
        folded: l.members.len(),
        est_speedup: t_unfused / t_fused.max(1e-30),
    }
}

fn weight_bytes_per_elem(p: crate::runtime::Precision) -> u64 {
    match p {
        crate::runtime::Precision::Fp32 => 4,
        crate::runtime::Precision::Fp16 => 2,
        _ => 1,
    }
}

// ---------------------------------------------------------------------------
// Step executors (direct function pointers in the step table)
// ---------------------------------------------------------------------------

fn run_fc(args: &StepArgs, prog: &CompiledProgram, arena: &mut ExecArena) -> Result<()> {
    let StepArgs::Fc { op, out, tail } = args else {
        unreachable!("run_fc bound to non-fc args");
    };
    let CompiledOp::Fc { input, m, layer, post, .. } = &prog.ops[*op] else {
        unreachable!("fc step bound to non-fc op");
    };
    debug_assert_ne!(out, input, "fused fc output must not alias its input");
    let mut o = mem::take(&mut arena.bufs[*out]);
    {
        let x = &arena.bufs[*input];
        let mut ops = [TailOp::Relu; MAX_TAIL];
        let mut nt = 0usize;
        if let Some(f) = post {
            ops[nt] = unary_tail(*f);
            nt += 1;
        }
        for t in tail {
            ops[nt] = t.bind(&arena.bufs);
            nt += 1;
        }
        layer.forward_ep(x, *m, &ops[..nt], &mut o);
    }
    arena.bufs[*out] = o;
    Ok(())
}

fn run_conv(args: &StepArgs, prog: &CompiledProgram, arena: &mut ExecArena) -> Result<()> {
    let StepArgs::Conv { op, out, tail } = args else {
        unreachable!("run_conv bound to non-conv args");
    };
    let CompiledOp::Conv2d { input, layer, post, geom, col, gbuf, .. } = &prog.ops[*op] else {
        unreachable!("conv step bound to non-conv op");
    };
    let mut colb = mem::take(&mut arena.bufs[*col]);
    let mut gb = mem::take(&mut arena.bufs[*gbuf]);
    let mut o = mem::take(&mut arena.bufs[*out]);
    {
        let x = &arena.bufs[*input];
        im2col(x, geom, layer.k, &mut colb);
        // unary-only tails commute elementwise with the NCHW scatter,
        // so the fold applies in gemm (pre-scatter) order — exactly
        // where the interpreter applies `post`
        let mut ops = [TailOp::Relu; MAX_TAIL];
        let mut nt = 0usize;
        if let Some(f) = post {
            ops[nt] = unary_tail(*f);
            nt += 1;
        }
        for t in tail {
            ops[nt] = t.bind(&arena.bufs);
            nt += 1;
        }
        layer.forward_ep(&colb, geom.rows, &ops[..nt], &mut gb);
        nchw_scatter(&gb, geom, layer.n, &mut o);
    }
    arena.bufs[*col] = colb;
    arena.bufs[*gbuf] = gb;
    arena.bufs[*out] = o;
    Ok(())
}

fn run_embed(args: &StepArgs, prog: &CompiledProgram, arena: &mut ExecArena) -> Result<()> {
    let StepArgs::Op { op } = args else {
        unreachable!("run_embed bound to producer args");
    };
    prog.exec_embed_at(*op, arena)
}

fn run_concat(args: &StepArgs, prog: &CompiledProgram, arena: &mut ExecArena) -> Result<()> {
    let StepArgs::Op { op } = args else {
        unreachable!("run_concat bound to producer args");
    };
    prog.exec_concat_at(*op, arena);
    Ok(())
}

fn run_unary(args: &StepArgs, prog: &CompiledProgram, arena: &mut ExecArena) -> Result<()> {
    let StepArgs::Op { op } = args else {
        unreachable!("run_unary bound to producer args");
    };
    prog.exec_unary_at(*op, arena);
    Ok(())
}

fn run_binary(args: &StepArgs, prog: &CompiledProgram, arena: &mut ExecArena) -> Result<()> {
    let StepArgs::Op { op } = args else {
        unreachable!("run_binary bound to producer args");
    };
    prog.exec_binary_at(*op, arena);
    Ok(())
}

#[cfg(test)]
mod tests {
    use crate::runtime::manifest::TensorMeta;
    use crate::runtime::native::build_native_artifact;
    use crate::runtime::weights::NamedTensor;
    use crate::runtime::{ArtifactMeta, HostTensor, Precision};
    use crate::util::json::Json;
    use crate::util::rng::Pcg32;

    fn named(name: &str, shape: &[usize], rng: &mut Pcg32) -> NamedTensor {
        let mut data = vec![0f32; shape.iter().product()];
        rng.fill_normal(&mut data, 0.0, 0.5);
        NamedTensor { name: name.to_string(), tensor: HostTensor::from_f32(shape, &data) }
    }

    fn meta_with(
        inputs: Vec<TensorMeta>,
        outputs: Vec<TensorMeta>,
        batch: usize,
        program: &str,
    ) -> ArtifactMeta {
        ArtifactMeta {
            name: "plan_t".into(),
            hlo: "plan_t.hlo.txt".into(),
            model: None,
            weights: None,
            weight_params: vec![],
            inputs,
            outputs,
            batch,
            precision: Precision::Fp32,
            program: Json::parse(program).unwrap(),
        }
    }

    fn tm(name: &str, shape: &[usize]) -> TensorMeta {
        TensorMeta { name: name.into(), dtype: crate::runtime::DType::F32, shape: shape.to_vec() }
    }

    fn bits(ts: &[HostTensor]) -> Vec<Vec<u32>> {
        ts.iter()
            .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
            .collect()
    }

    #[test]
    fn gru_style_chain_folds_and_matches_interpreter_bitwise() {
        let mut rng = Pcg32::seeded(71);
        let weights = vec![
            named("wx", &[6, 4], &mut rng),
            named("bx", &[6], &mut rng),
            named("wh", &[6, 4], &mut rng),
            named("wo", &[3, 6], &mut rng),
        ];
        let prog = r#"[
            {"op": "fc", "out": "hx", "in": "x", "w": "wx", "b": "bx", "act": "none"},
            {"op": "fc", "out": "hh", "in": "h", "w": "wh", "act": "none"},
            {"op": "binary", "fn": "add", "out": "pre", "a": "hx", "b": "hh"},
            {"op": "unary", "fn": "tanh", "out": "hn", "in": "pre"},
            {"op": "fc", "out": "y", "in": "hn", "w": "wo", "act": "none"}
        ]"#;
        let meta = meta_with(
            vec![tm("x", &[2, 4]), tm("h", &[2, 4])],
            vec![tm("y", &[2, 3]), tm("hn", &[2, 6])],
            2,
            prog,
        );
        let art = build_native_artifact(meta, &weights, Precision::Fp32, 1).unwrap();
        let rep = art.fusion_report();
        assert_eq!(rep.chains.len(), 1, "{}", rep.summary());
        assert_eq!(rep.chains[0].signature, "FC>Elementwise>Elementwise");
        assert_eq!(rep.chains[0].folded, 2);
        assert_eq!(rep.plan_steps, rep.interp_ops - 2);
        let inputs = art.synth_inputs(11);
        let a = art.run_compiled(&inputs).unwrap();
        let b = art.run_interpreted(&inputs).unwrap();
        assert_eq!(bits(&a), bits(&b));
    }

    #[test]
    fn chain_value_consumed_twice_refuses_fusion_but_still_matches() {
        let mut rng = Pcg32::seeded(72);
        let weights = vec![named("w", &[4, 4], &mut rng)];
        // t is read by both the unary and the binary: no sole consumer,
        // so nothing folds — and both engines still agree bitwise.
        let prog = r#"[
            {"op": "fc", "out": "t", "in": "x", "w": "w", "act": "none"},
            {"op": "unary", "fn": "sigmoid", "out": "s", "in": "t"},
            {"op": "binary", "fn": "mul", "out": "y", "a": "s", "b": "t"}
        ]"#;
        let meta =
            meta_with(vec![tm("x", &[1, 4])], vec![tm("y", &[1, 4])], 1, prog);
        let art = build_native_artifact(meta, &weights, Precision::Fp32, 1).unwrap();
        assert!(art.fusion_report().chains.is_empty(), "{}", art.fusion_report().summary());
        let inputs = art.synth_inputs(5);
        assert_eq!(
            bits(&art.run_compiled(&inputs).unwrap()),
            bits(&art.run_interpreted(&inputs).unwrap())
        );
    }

    #[test]
    fn conv_folds_trailing_unary_and_matches_interpreter_bitwise() {
        let mut rng = Pcg32::seeded(73);
        let weights = vec![named("cw", &[2, 1, 3, 3], &mut rng), named("cb", &[2], &mut rng)];
        let prog = r#"[
            {"op": "conv2d", "out": "c", "in": "img", "w": "cw", "b": "cb", "act": "relu",
             "stride": 1, "pad": [1, 1]},
            {"op": "unary", "fn": "tanh", "out": "y", "in": "c"}
        ]"#;
        let meta = meta_with(
            vec![tm("img", &[1, 1, 5, 5])],
            vec![tm("y", &[1, 2, 5, 5])],
            1,
            prog,
        );
        let art = build_native_artifact(meta, &weights, Precision::Fp32, 1).unwrap();
        let rep = art.fusion_report();
        assert_eq!(rep.chains.len(), 1, "{}", rep.summary());
        assert_eq!(rep.chains[0].signature, "Conv>Elementwise");
        let inputs = art.synth_inputs(7);
        assert_eq!(
            bits(&art.run_compiled(&inputs).unwrap()),
            bits(&art.run_interpreted(&inputs).unwrap())
        );
    }

    #[test]
    fn folded_activation_counts_and_speedup_estimates_are_sane() {
        let mut rng = Pcg32::seeded(74);
        let weights = vec![named("w", &[4, 4], &mut rng), named("w2", &[2, 4], &mut rng)];
        let prog = r#"[
            {"op": "fc", "out": "t", "in": "x", "w": "w", "act": "sigmoid"},
            {"op": "fc", "out": "y", "in": "t", "w": "w2", "act": "none"},
            {"op": "unary", "fn": "relu", "out": "z", "in": "y"}
        ]"#;
        let meta =
            meta_with(vec![tm("x", &[1, 4])], vec![tm("z", &[1, 2])], 1, prog);
        let art = build_native_artifact(meta, &weights, Precision::Fp32, 1).unwrap();
        let rep = art.fusion_report();
        assert_eq!(rep.folded_activations, 1);
        assert_eq!(rep.chains.len(), 1);
        // memory-bound tiny chain: the roofline estimate must be >= 1
        assert!(rep.chains[0].est_speedup >= 1.0, "{}", rep.chains[0].est_speedup);
        assert!(rep.summary().contains("fused"));
    }
}
