//! Execution precision variants (§3.2): the numeric contract a backend
//! serves an artifact at, and the per-precision accuracy bound the
//! parity tests hold every backend to.
//!
//! The manifest's `precision` field records what an artifact *contains*
//! (`recsys_int8_b16` bakes int8 weights into the HLO); a
//! [`super::backend::ExecBackend`] additionally has an *execution*
//! precision — the native backend re-quantizes fp32 weight files to any
//! of these at load time.

use anyhow::{bail, Result};

/// Numeric path an artifact executes on (Fig 6's four GEMM paths).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// fp32 storage + compute (the MKL-stand-in baseline).
    Fp32,
    /// fp16 weight storage, fp32 compute (Fig 6a bandwidth win).
    Fp16,
    /// int8 multiplies, int32 accumulation (Fig 6a).
    I8Acc32,
    /// int8 multiplies, int16 accumulation + sparse outlier split
    /// (Fig 6b / §3.2.1).
    I8Acc16,
}

impl Precision {
    /// Every execution precision, lowest-error first.
    pub fn all() -> [Precision; 4] {
        [Precision::Fp32, Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16]
    }

    /// Manifest/CLI spelling. `int8` is accepted as an alias for the
    /// acc32 path (what the AOT int8 artifacts contain).
    pub fn from_manifest(s: &str) -> Result<Precision> {
        Ok(match s {
            "fp32" => Precision::Fp32,
            "fp16" => Precision::Fp16,
            "int8" | "i8acc32" => Precision::I8Acc32,
            "i8acc16" => Precision::I8Acc16,
            other => bail!("unknown precision in manifest: {other}"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::I8Acc32 => "i8acc32",
            Precision::I8Acc16 => "i8acc16",
        }
    }

    /// Weight-storage bytes per fp32 element (the Fig-6 traffic ratios).
    pub fn weight_bytes_per_elem(self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
            Precision::I8Acc32 | Precision::I8Acc16 => 1.0,
        }
    }

    /// Minimum end-to-end SQNR (vs the fp32 reference) a backend must
    /// sustain at this precision — the [`crate::quant::error`] tolerance
    /// model the parity tests assert. The int8 bound is the §3.2.2
    /// technique-3 acceptability threshold (20 dB ≈ 10% relative noise,
    /// the "skip quantization when the error is too high" cutoff); fp16
    /// and fp32 bounds follow from their mantissa widths with slack for
    /// accumulation-order differences.
    pub fn min_sqnr_db(self) -> f64 {
        match self {
            Precision::Fp32 => 80.0,
            Precision::Fp16 => 40.0,
            Precision::I8Acc32 | Precision::I8Acc16 => 20.0,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_roundtrip() {
        for p in Precision::all() {
            assert_eq!(Precision::from_manifest(p.as_str()).unwrap(), p);
        }
        assert_eq!(Precision::from_manifest("int8").unwrap(), Precision::I8Acc32);
        assert!(Precision::from_manifest("fp64").is_err());
    }

    #[test]
    fn bounds_loosen_with_narrower_types() {
        assert!(Precision::Fp32.min_sqnr_db() > Precision::Fp16.min_sqnr_db());
        assert!(Precision::Fp16.min_sqnr_db() > Precision::I8Acc32.min_sqnr_db());
    }

    #[test]
    fn traffic_ratios_match_fig6() {
        assert_eq!(Precision::Fp32.weight_bytes_per_elem(), 4.0);
        assert_eq!(Precision::Fp16.weight_bytes_per_elem(), 2.0);
        assert_eq!(Precision::I8Acc16.weight_bytes_per_elem(), 1.0);
    }
}
