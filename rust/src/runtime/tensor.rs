//! Host-side tensors exchanged with the PJRT runtime.

use anyhow::{bail, Result};

/// Element types used by the artifacts (matches the AOT manifest codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }

    pub fn from_manifest(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "i32" => DType::I32,
            other => bail!("unknown dtype in manifest: {other}"),
        })
    }

    #[cfg(feature = "pjrt")]
    pub fn to_xla(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I8 => xla::ElementType::S8,
            DType::I32 => xla::ElementType::S32,
        }
    }
}

/// A host tensor: dtype + shape + raw little-endian bytes.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn from_f32(shape: &[usize], vals: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::F32, shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], vals: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::I32, shape: shape.to_vec(), data }
    }

    pub fn from_i8(shape: &[usize], vals: &[i8]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        HostTensor {
            dtype: DType::I8,
            shape: shape.to_vec(),
            data: vals.iter().map(|&v| v as u8).collect(),
        }
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elem_count() * self.dtype.size()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decode into a reusable buffer (clear + refill): zero heap
    /// allocations once the buffer is at capacity — the form the
    /// native backend's execution arena uses on the request path.
    pub fn copy_f32_into(&self, out: &mut Vec<f32>) -> Result<()> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        out.clear();
        out.extend(
            self.data.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        Ok(())
    }

    /// i32 variant of [`Self::copy_f32_into`].
    pub fn copy_i32_into(&self, out: &mut Vec<i32>) -> Result<()> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not I32", self.dtype);
        }
        out.clear();
        out.extend(
            self.data.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        Ok(())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not I32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i8(&self) -> Result<Vec<i8>> {
        if self.dtype != DType::I8 {
            bail!("tensor is {:?}, not I8", self.dtype);
        }
        Ok(self.data.iter().map(|&b| b as i8).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = HostTensor::from_f32(&[2, 2], &[1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.byte_len(), 16);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, -2.5, 3.25, 0.0]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn i32_roundtrip() {
        let t = HostTensor::from_i32(&[3], &[-1, 0, i32::MAX]);
        assert_eq!(t.as_i32().unwrap(), vec![-1, 0, i32::MAX]);
    }

    #[test]
    fn i8_roundtrip() {
        let t = HostTensor::from_i8(&[4], &[-128, -1, 0, 127]);
        assert_eq!(t.as_i8().unwrap(), vec![-128, -1, 0, 127]);
        assert_eq!(t.byte_len(), 4);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::from_f32(&[3], &[1.0]);
    }
}
