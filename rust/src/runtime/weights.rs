//! Reader for the DCIW weights binary written by `python/compile/aot.py`.
//!
//! Format (little-endian):
//! ```text
//! magic "DCIW" | u32 version | u32 n_tensors
//! per tensor: u32 name_len | name | u8 dtype(0=f32,1=i8,2=i32) |
//!             u32 ndim | u64 dims... | raw data
//! ```

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{DType, HostTensor};

/// A named weight tensor.
#[derive(Debug, Clone)]
pub struct NamedTensor {
    pub name: String,
    pub tensor: HostTensor,
}

/// Read every tensor in a DCIW file, preserving order (the order defines
/// the leading HLO parameters).
pub fn read_weights_file(path: &Path) -> Result<Vec<NamedTensor>> {
    let data = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_weights_bytes(&data)
}

pub fn read_weights_bytes(data: &[u8]) -> Result<Vec<NamedTensor>> {
    let mut cur = std::io::Cursor::new(data);
    let mut magic = [0u8; 4];
    cur.read_exact(&mut magic)?;
    if &magic != b"DCIW" {
        bail!("bad magic: {:?}", magic);
    }
    let version = read_u32(&mut cur)?;
    if version != 1 {
        bail!("unsupported weights version {version}");
    }
    let n = read_u32(&mut cur)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut cur)? as usize;
        let mut name = vec![0u8; name_len];
        cur.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        let mut dcode = [0u8; 1];
        cur.read_exact(&mut dcode)?;
        let dtype = match dcode[0] {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            other => bail!("unknown dtype code {other} for {name}"),
        };
        let ndim = read_u32(&mut cur)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            cur.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let count: usize = shape.iter().product::<usize>().max(1);
        let nbytes = count * dtype.size();
        let mut raw = vec![0u8; nbytes];
        cur.read_exact(&mut raw)
            .with_context(|| format!("truncated data for tensor {name}"))?;
        out.push(NamedTensor { name, tensor: HostTensor { dtype, shape, data: raw } });
    }
    Ok(out)
}

fn read_u32(cur: &mut std::io::Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Serialize tensors to the DCIW format (the Rust mirror of
/// `aot.write_weights`). Used by tests and tools that synthesize
/// native-backend artifact directories without the Python toolchain.
pub fn write_weights_bytes(tensors: &[NamedTensor]) -> Vec<u8> {
    let mut out = b"DCIW".to_vec();
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        out.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
        out.extend_from_slice(t.name.as_bytes());
        out.push(match t.tensor.dtype {
            DType::F32 => 0,
            DType::I8 => 1,
            DType::I32 => 2,
        });
        out.extend_from_slice(&(t.tensor.shape.len() as u32).to_le_bytes());
        for &d in &t.tensor.shape {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.extend_from_slice(&t.tensor.data);
    }
    out
}

/// Write a DCIW weights file.
pub fn write_weights_file(path: &Path, tensors: &[NamedTensor]) -> Result<()> {
    std::fs::write(path, write_weights_bytes(tensors))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tensor(out: &mut Vec<u8>, name: &str, dcode: u8, dims: &[u64], data: &[u8]) {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(dcode);
        out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for d in dims {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(data);
    }

    fn header(n: u32) -> Vec<u8> {
        let mut v = b"DCIW".to_vec();
        v.extend_from_slice(&1u32.to_le_bytes());
        v.extend_from_slice(&n.to_le_bytes());
        v
    }

    #[test]
    fn roundtrip_two_tensors() {
        let mut buf = header(2);
        let f: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0].iter().flat_map(|v| v.to_le_bytes()).collect();
        write_tensor(&mut buf, "w", 0, &[2, 2], &f);
        write_tensor(&mut buf, "idx", 2, &[2], &[7, 0, 0, 0, 9, 0, 0, 0]);
        let ts = read_weights_bytes(&buf).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "w");
        assert_eq!(ts[0].tensor.shape, vec![2, 2]);
        assert_eq!(ts[0].tensor.as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ts[1].tensor.as_i32().unwrap(), vec![7, 9]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_weights_bytes(b"NOPE").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut buf = header(1);
        write_tensor(&mut buf, "w", 0, &[4], &[0u8; 8]); // needs 16 bytes
        assert!(read_weights_bytes(&buf).is_err());
    }

    #[test]
    fn writer_reader_roundtrip() {
        let tensors = vec![
            NamedTensor { name: "w".into(), tensor: HostTensor::from_f32(&[2, 3], &[0.5; 6]) },
            NamedTensor { name: "q".into(), tensor: HostTensor::from_i8(&[4], &[-1, 0, 1, 127]) },
            NamedTensor { name: "idx".into(), tensor: HostTensor::from_i32(&[2], &[7, -9]) },
        ];
        let bytes = write_weights_bytes(&tensors);
        let back = read_weights_bytes(&bytes).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in tensors.iter().zip(&back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.tensor.dtype, b.tensor.dtype);
            assert_eq!(a.tensor.shape, b.tensor.shape);
            assert_eq!(a.tensor.data, b.tensor.data);
        }
    }

    #[test]
    fn rejects_bad_version() {
        let mut v = b"DCIW".to_vec();
        v.extend_from_slice(&9u32.to_le_bytes());
        v.extend_from_slice(&0u32.to_le_bytes());
        assert!(read_weights_bytes(&v).is_err());
    }
}
