//! Measurement harness used by `rust/benches/*` (criterion is
//! unavailable offline). Provides warmup + timed iterations, outlier-
//! robust medians, and Gop/s / GB/s reporting helpers so every bench
//! prints the same rows/series the paper's tables and figures report.

use std::hint::black_box;
use std::time::Instant;

use super::stats::{fmt_ns, Samples};

/// One measurement result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p05_ns: f64,
    pub p95_ns: f64,
}

impl Measurement {
    /// Throughput in Gop/s given the op count per iteration.
    pub fn gops(&self, ops_per_iter: f64) -> f64 {
        ops_per_iter / self.median_ns
    }

    /// Bandwidth in GB/s given bytes touched per iteration.
    pub fn gbps(&self, bytes_per_iter: f64) -> f64 {
        bytes_per_iter / self.median_ns
    }
}

/// Run `f` with warmup, then sample wall time until `budget_ms` of
/// measurement is spent (at least `min_samples` samples).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    bench_cfg(name, 100, 10, &mut f)
}

/// Configurable variant: `budget_ms` of total measurement time,
/// `min_samples` timed samples minimum.
pub fn bench_cfg<F: FnMut()>(
    name: &str,
    budget_ms: u64,
    min_samples: usize,
    f: &mut F,
) -> Measurement {
    // warmup + calibration: find iters-per-sample so one sample >= ~1ms
    f();
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters_per_sample = ((1_000_000.0 / once_ns).ceil() as u64).clamp(1, 1_000_000);

    let mut samples = Samples::new();
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    while samples.len() < min_samples || start.elapsed() < budget {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        if samples.len() > 10_000 {
            break;
        }
    }
    let mean = samples.mean();
    Measurement {
        name: name.to_string(),
        iters: samples.len() as u64 * iters_per_sample,
        median_ns: samples.p50(),
        mean_ns: mean,
        p05_ns: samples.percentile(5.0),
        p95_ns: samples.percentile(95.0),
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn keep<T>(v: T) -> T {
    black_box(v)
}

/// Table printer: fixed-width columns, paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>().trim_end()
        );
        for row in &self.rows {
            line(row);
        }
    }
}

/// Where the perf-trajectory artifacts (`BENCH_*.json`) live: the repo
/// root, found by walking up from the CWD to the first directory
/// holding `ROADMAP.md` (benches run from `rust/`). Falls back to the
/// CWD outside a checkout.
pub fn bench_artifact_dir() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    for _ in 0..4 {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => break,
        }
    }
    std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."))
}

/// Write one `BENCH_<name>.json` perf artifact to the repo root and
/// return the path it landed at.
pub fn write_bench_json(filename: &str, json: &str) -> std::path::PathBuf {
    let path = bench_artifact_dir().join(filename);
    if let Err(e) = std::fs::write(&path, json) {
        eprintln!("warning: could not write {}: {e}", path.display());
    }
    path
}

/// Report a measurement line in a uniform format.
pub fn report(m: &Measurement) {
    println!(
        "{:<44} {:>12}/iter  (p05 {}, p95 {}, n={})",
        m.name,
        fmt_ns(m.median_ns),
        fmt_ns(m.p05_ns),
        fmt_ns(m.p95_ns),
        m.iters,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let m = bench_cfg("spin", 20, 5, &mut || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(keep(i));
            }
        });
        assert!(m.median_ns > 0.0);
        assert!(m.iters >= 5);
        assert!(m.p05_ns <= m.p95_ns);
    }

    #[test]
    fn gops_math() {
        let m = Measurement {
            name: "x".into(),
            iters: 1,
            median_ns: 1e3,
            mean_ns: 1e3,
            p05_ns: 1e3,
            p95_ns: 1e3,
        };
        // 2e6 ops in 1us = 2000 Gop/s
        assert!((m.gops(2e6) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
