//! IEEE-754 binary16 conversion (the `half` crate is unavailable offline).
//!
//! Used by the fp16-storage GEMM path (`gemm::fp16`): weights are stored
//! as u16 half floats — halving weight memory traffic, the entire win in
//! the paper's bandwidth-bound regime (Fig 6a) — and widened to f32 for
//! compute, mirroring x86 `vcvtph2ps`.

/// Convert an f32 to IEEE binary16 (round-to-nearest-even).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m | ((mant >> 13) as u16);
    }
    // rebias: f32 exp-127 + 15
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e16 <= 0 {
        // subnormal or zero
        if e16 < -10 {
            return sign; // underflow to zero
        }
        let m = mant | 0x0080_0000; // implicit bit
        let shift = (14 - e16) as u32;
        let half = 1u32 << (shift - 1);
        let mut v = m >> shift;
        // round to nearest even
        if (m & (half * 2 - 1)) > half || ((m & (half * 2 - 1)) == half && (v & 1) == 1) {
            v += 1;
        }
        return sign | v as u16;
    }
    let mut v = ((e16 as u32) << 10) | (mant >> 13);
    // round to nearest even on the 13 dropped bits
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (v & 1) == 1) {
        v += 1; // may carry into exponent — that is correct behaviour
    }
    sign | v as u16
}

/// Convert IEEE binary16 bits to f32.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = 127 - 15 - 10;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((e + 10) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize a slice to f16 storage.
pub fn to_f16_vec(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(f16_to_f32(f32_to_f16(x)), x, "{x}");
        }
    }

    #[test]
    fn relative_error_within_half_ulp() {
        // f16 has 11 bits of significand: rel err <= 2^-11
        let mut x = 1e-3f32;
        while x < 6e4 {
            let r = f16_to_f32(f32_to_f16(x));
            assert!(((r - x) / x).abs() <= 1.0 / 2048.0 + 1e-7, "{x} -> {r}");
            x *= 1.37;
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f32_to_f16(0.0), 0);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(-f32::INFINITY), 0xfc00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f32_to_f16(1e30), 0x7c00); // overflow to inf
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0x7bff), 65504.0); // f16::MAX
    }

    #[test]
    fn subnormals_roundtrip() {
        let tiny = 5.96e-8f32; // smallest positive f16 subnormal
        let h = f32_to_f16(tiny);
        assert!(h > 0 && h < 0x400);
        let back = f16_to_f32(h);
        assert!((back - tiny).abs() / tiny < 0.5);
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(2.0), 0x4000);
        assert_eq!(f32_to_f16(-1.5), 0xbe00);
        assert_eq!(f32_to_f16(0.5), 0x3800);
        assert_eq!(f32_to_f16(65504.0), 0x7bff);
    }
}
