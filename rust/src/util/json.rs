//! Minimal JSON parser for the artifact manifest (serde is unavailable
//! offline). Supports the full JSON grammar minus exotic number forms;
//! good enough for `artifacts/manifest.json` and the report files the
//! CLI emits.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Object field lookup; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize (used by the CLI report writers).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    x.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

/// Error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    if s.len() < len {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&s[..len]).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
        assert_eq!(v.get("a").as_arr().unwrap()[1].get("b").as_str(), Some("x"));
        assert!(v.get("c").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let src = r#"{"artifacts": {"m": {"hlo": "m.hlo.txt", "batch": 16}}, "version": 1}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
