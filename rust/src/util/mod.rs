//! Small self-contained substrates the rest of the crate builds on.
//!
//! The build environment is fully offline (see DESIGN.md substitutions):
//! no serde / rand / criterion / half crates are available, so this
//! module provides the minimal equivalents — a JSON parser for the
//! artifact manifest, a PCG32 PRNG for workload synthesis, an IEEE-754
//! half-precision converter for the fp16 GEMM path, streaming statistics
//! for latency tracking, and a measurement harness used by `benches/`.

pub mod bench;
pub mod f16;
pub mod json;
pub mod rng;
pub mod stats;
