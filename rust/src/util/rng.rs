//! PCG32 pseudo-random number generator (O'Neill 2014).
//!
//! Deterministic, seedable, and good enough for workload synthesis,
//! quantization experiments and property tests. Replaces the `rand`
//! crate, which is unavailable offline.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        // avoid log(0)
        let u1 = (self.next_u32() as f64 + 1.0) / (u32::MAX as f64 + 2.0);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal f32 with given mean/std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.uniform();
        -u.ln() / lambda
    }

    /// Poisson-ish arrival count for a window (Knuth, small means).
    pub fn poisson(&mut self, mean: f64) -> u32 {
        if mean > 30.0 {
            // normal approximation for large means
            let v = self.normal() * mean.sqrt() + mean;
            return v.max(0.0).round() as u32;
        }
        let l = (-mean).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-distributed value in [0, n) with exponent s (embedding-id skew).
    /// Uses rejection-inversion (Hörmann); fine for the simulator scale.
    pub fn zipf(&mut self, n: u32, s: f64) -> u32 {
        // simple inverse-CDF on a truncated harmonic approximation
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.below(n);
        }
        let u = self.uniform();
        // H(x) ~ (x^(1-s) - 1)/(1-s) for s != 1, ln(x) for s == 1
        let nf = n as f64;
        let x = if (s - 1.0).abs() < 1e-9 {
            nf.powf(u)
        } else {
            let h_n = (nf.powf(1.0 - s) - 1.0) / (1.0 - s);
            ((u * h_n * (1.0 - s)) + 1.0).powf(1.0 / (1.0 - s))
        };
        (x.floor() as u32).min(n - 1)
    }

    /// Fill a slice with standard-normal f32 values scaled by `std`.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            if t < *w {
                return i;
            }
            t -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut rng = Pcg32::seeded(13);
        let n = 10_000u32;
        let mut head = 0usize;
        let total = 10_000;
        for _ in 0..total {
            if rng.zipf(n, 1.1) < n / 100 {
                head += 1;
            }
        }
        // with skew, the top 1% of ids gets far more than 1% of traffic
        assert!(head > total / 10, "head {head}");
    }

    #[test]
    fn poisson_mean_tracks() {
        let mut rng = Pcg32::seeded(17);
        let n = 5000;
        let m: f64 = (0..n).map(|_| rng.poisson(4.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 4.0).abs() < 0.2, "mean {m}");
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut rng = Pcg32::seeded(19);
        let w = [1.0, 9.0];
        let picks = (0..10_000).filter(|_| rng.weighted_choice(&w) == 1).count();
        assert!(picks > 8_500 && picks < 9_500, "{picks}");
    }
}
