//! Streaming statistics: latency percentiles, histograms, running
//! min/max/mean — used by the coordinator's metrics, the fleet telemetry
//! agent (§3.1) and the bench harness.

/// Reservoir of raw samples with percentile queries. For the sample
/// counts in this repo (<= millions) keeping raw values is simplest and
/// exact.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    vals: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.vals.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.vals.iter().sum::<f64>() / self.vals.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.vals.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.vals.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.vals.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Exact percentile (nearest-rank with linear interpolation).
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let p = p.clamp(0.0, 100.0) / 100.0;
        let idx = p * (self.vals.len() - 1) as f64;
        let lo = idx.floor() as usize;
        let hi = idx.ceil() as usize;
        if lo == hi {
            self.vals[lo]
        } else {
            let f = idx - lo as f64;
            self.vals[lo] * (1.0 - f) + self.vals[hi] * f
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn p999(&mut self) -> f64 {
        self.percentile(99.9)
    }
}

/// Running scalar statistics without sample storage (Welford).
#[derive(Debug, Clone, Copy, Default)]
pub struct Running {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, o: &Running) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *o;
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        let mean = self.mean + d * o.n as f64 / n as f64;
        let m2 = self.m2 + o.m2 + d * d * self.n as f64 * o.n as f64 / n as f64;
        *self = Running { n, mean, m2, min: self.min.min(o.min), max: self.max.max(o.max) };
    }
}

/// Fixed-range histogram (used by the quantization calibrator).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub under: u64,
    pub over: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], under: 0, over: 0 }
    }

    pub fn push(&mut self, v: f64) {
        if v < self.lo {
            self.under += 1;
        } else if v >= self.hi {
            self.over += 1;
        } else {
            let f = (v - self.lo) / (self.hi - self.lo);
            let n = self.counts.len();
            let idx = ((f * n as f64) as usize).min(n - 1);
            self.counts[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.under + self.over
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }
}

/// Pretty-print nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.0} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-9);
        assert!(s.p999() >= s.p99());
    }

    #[test]
    fn running_matches_samples() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.7 - 3.0).collect();
        let mut r = Running::new();
        let mut s = Samples::new();
        for &x in &xs {
            r.push(x);
            s.push(x);
        }
        assert!((r.mean - s.mean()).abs() < 1e-9);
        assert!((r.std() - s.std()).abs() < 1e-9);
        assert_eq!(r.min, s.min());
        assert_eq!(r.max, s.max());
    }

    #[test]
    fn running_merge_equals_sequential() {
        let xs: Vec<f64> = (0..57).map(|i| (i as f64).sin()).collect();
        let mut all = Running::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Running::new();
        let mut b = Running::new();
        for &x in &xs[..20] {
            a.push(x);
        }
        for &x in &xs[20..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean - all.mean).abs() < 1e-12);
        assert!((a.var() - all.var()).abs() < 1e-12);
        assert_eq!(a.n, all.n);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(99.0);
        assert!(h.counts.iter().all(|&c| c == 1));
        assert_eq!(h.under, 1);
        assert_eq!(h.over, 1);
        assert_eq!(h.total(), 12);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-9);
    }
}
