//! Autoscale seals: through a simulated diurnal peak the controller
//! must grow the tier, shed must recover, capacity must come back down
//! after the trough — and none of it may touch numerics.
//!
//! The scaling episode is driven synchronously (the test owns the tick
//! loop: observe → `ScalePolicy::decide` → `resize_executors`) so the
//! phase structure is deterministic; the threaded loop around the same
//! pieces is covered by `AutoscaleController`'s own test and the
//! `dcinfer autoscale` CI smoke. Pressure is manufactured by bursting
//! far past the admission queue bound, which overloads the tier at any
//! machine speed.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dcinfer::autoscale::{
    Observation, PolicyState, ScaleAction, ScaleDecision, ScalePolicy, Scalable, TickSignals,
};
use dcinfer::coordinator::{
    FrontendConfig, IndexSkew, InferError, InferRequest, InferResponse, ServingFrontend,
};
use dcinfer::embedding::{cache::CacheOutcome, HotRowCache};
use dcinfer::models::RecSysService;
use dcinfer::runtime::{synthetic_artifacts_dir, BackendSpec, Manifest, Precision};
use dcinfer::util::rng::Pcg32;

const RECV_TIMEOUT: Duration = Duration::from_secs(60);

fn start_frontend(
    dir: &std::path::Path,
    executors: usize,
    max_queue_depth: usize,
) -> (Arc<ServingFrontend>, RecSysService) {
    let manifest = Manifest::load(dir).unwrap();
    let service = RecSysService::from_manifest(&manifest).unwrap();
    let frontend = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.to_path_buf(),
            executors,
            max_wait_us: 500.0,
            max_queue_depth,
            backend: BackendSpec::native(Precision::Fp32),
            ..Default::default()
        },
        vec![Arc::new(service.clone())],
    )
    .unwrap();
    (Arc::new(frontend), service)
}

/// One synchronous controller tick: diff cumulative counters into
/// per-tick signals, decide, apply. Mirrors `controller_loop` exactly,
/// minus the thread and the wall clock.
fn tick(
    frontend: &Arc<ServingFrontend>,
    policy: &ScalePolicy,
    state: &mut PolicyState,
    prev: &mut Observation,
    log: &mut Vec<ScaleDecision>,
) {
    let now = frontend.observe();
    let signals = TickSignals {
        served: now.served.saturating_sub(prev.served),
        shed: now.shed.saturating_sub(prev.shed),
        failed: now.failed.saturating_sub(prev.failed),
        queue_depth: now.queue_depth,
        p99_ms: now.p99_ms,
        deadline_ms: now.deadline_ms,
        capacity: frontend.executor_capacity(),
    };
    *prev = now;
    let mut d = policy.decide(state, signals);
    if d.action != ScaleAction::Hold {
        d.to = frontend.resize_executors(d.to).unwrap();
    }
    log.push(d);
}

/// The p99 signal is a cumulative-window trailing indicator: once a
/// peak congests it, it never comes back down within one run. Disable
/// it so phase transitions are driven by the fast signals (shed, queue)
/// the burst structure controls deterministically.
fn test_policy() -> ScalePolicy {
    ScalePolicy {
        min_capacity: 1,
        max_capacity: 4,
        shed_frac_up: 0.01,
        queue_depth_up: 32,
        p99_frac_up: 1e9,
        queue_depth_down: 8,
        p99_frac_down: 1e8,
        quiet_ticks_down: 2,
        cooldown_ticks: 1,
        step_up: 2,
        step_down: 1,
    }
}

fn drain(pending: &mut Vec<std::sync::mpsc::Receiver<InferResponse>>) -> (u64, u64, u64) {
    let (mut ok, mut shed, mut err) = (0u64, 0u64, 0u64);
    for rx in pending.drain(..) {
        let resp = rx.recv_timeout(RECV_TIMEOUT).expect("response never arrived");
        match &resp.outcome {
            Ok(_) => ok += 1,
            Err(InferError::Overloaded(_)) => shed += 1,
            Err(_) => err += 1,
        }
    }
    (ok, shed, err)
}

#[test]
fn controller_scales_up_through_peak_and_back_down_after_trough() {
    let dir = synthetic_artifacts_dir("autoscale_peak").expect("fixture");
    let (frontend, service) = start_frontend(&dir, 1, 64);
    let policy = test_policy();
    let mut state = PolicyState::default();
    let mut prev = frontend.observe();
    let mut log: Vec<ScaleDecision> = Vec::new();
    let mut rng = Pcg32::seeded(42);
    let mut id = 0u64;
    let mut synth = |rng: &mut Pcg32, id: &mut u64| {
        let mut req = service.synth_request_skewed(*id, rng, 200.0, IndexSkew::Zipf(1.0));
        req.arrival = Instant::now();
        *id += 1;
        req
    };

    // --- trough: a trickle the single executor absorbs ---------------
    for _ in 0..3 {
        let mut pending = Vec::new();
        for _ in 0..16 {
            let req = synth(&mut rng, &mut id);
            pending.push(frontend.submit(req).unwrap());
            std::thread::sleep(Duration::from_micros(300));
        }
        let (_ok, shed, err) = drain(&mut pending);
        assert_eq!((shed, err), (0, 0), "trough traffic must serve cleanly");
        tick(&frontend, &policy, &mut state, &mut prev, &mut log);
    }
    assert_eq!(frontend.executor_capacity(), 1, "no pressure yet: {log:#?}");

    // --- peak: bursts 3x the queue bound force sheds at any speed ----
    let (mut peak_sent, mut peak_ok, mut peak_shed) = (0u64, 0u64, 0u64);
    let mut rounds = 0;
    while frontend.executor_capacity() < policy.max_capacity && rounds < 12 {
        let mut pending = Vec::new();
        for _ in 0..192 {
            pending.push(frontend.submit(synth(&mut rng, &mut id)).unwrap());
        }
        peak_sent += 192;
        tick(&frontend, &policy, &mut state, &mut prev, &mut log);
        let (ok, shed, err) = drain(&mut pending);
        peak_ok += ok;
        peak_shed += shed;
        assert_eq!(err, 0, "peak traffic may shed but never hard-fail");
        rounds += 1;
    }
    assert!(
        frontend.executor_capacity() >= 3,
        "controller never scaled up under sustained shed: {log:#?}"
    );
    assert!(log.iter().any(|d| d.action == ScaleAction::Up), "{log:#?}");
    assert!(peak_shed > 0, "bursts past the queue bound must shed");
    assert_eq!(peak_ok + peak_shed, peak_sent);

    // --- sustained peak at scaled capacity: shed recovers ------------
    // paced inside the queue bound, the grown tier carries the load;
    // the acceptance bar is < 5% shed over this window
    let (mut win_sent, mut win_shed) = (0u64, 0u64);
    for _ in 0..4 {
        let mut pending = Vec::new();
        for _ in 0..48 {
            pending.push(frontend.submit(synth(&mut rng, &mut id)).unwrap());
            std::thread::sleep(Duration::from_micros(200));
        }
        win_sent += 48;
        let (_ok, shed, err) = drain(&mut pending);
        win_shed += shed;
        assert_eq!(err, 0);
        tick(&frontend, &policy, &mut state, &mut prev, &mut log);
    }
    assert!(
        (win_shed as f64) < 0.05 * win_sent as f64,
        "shed did not recover after scale-up: {win_shed}/{win_sent}"
    );

    // --- trough again: the controller walks capacity back to min -----
    let mut rounds = 0;
    while frontend.executor_capacity() > 1 && rounds < 30 {
        let mut pending = Vec::new();
        for _ in 0..4 {
            pending.push(frontend.submit(synth(&mut rng, &mut id)).unwrap());
            std::thread::sleep(Duration::from_micros(300));
        }
        let _ = drain(&mut pending);
        tick(&frontend, &policy, &mut state, &mut prev, &mut log);
        rounds += 1;
    }
    assert_eq!(frontend.executor_capacity(), 1, "idle capacity never reclaimed: {log:#?}");
    assert!(log.iter().any(|d| d.action == ScaleAction::Down), "{log:#?}");

    // cooldown: applied scale events are never on adjacent ticks
    let events: Vec<u64> =
        log.iter().filter(|d| d.action != ScaleAction::Hold).map(|d| d.tick).collect();
    for w in events.windows(2) {
        assert!(w[1] > w[0] + 1, "adjacent-tick scale events {w:?} violate cooldown: {log:#?}");
    }

    frontend.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn responses_stay_bit_identical_to_a_fixed_capacity_reference() {
    let dir = synthetic_artifacts_dir("autoscale_bits").expect("fixture");
    // elastic tier starts at 1 executor and is resized mid-load;
    // reference tier holds 3 executors for the whole run
    let (elastic, service) = start_frontend(&dir, 1, usize::MAX);
    let (fixed, _) = start_frontend(&dir, 3, usize::MAX);

    // one request stream, submitted verbatim to both tiers
    let mut rng = Pcg32::seeded(7);
    let requests: Vec<InferRequest> = (0..240)
        .map(|i| service.synth_request_skewed(i, &mut rng, 10_000.0, IndexSkew::Zipf(1.0)))
        .collect();

    let mut got_e = Vec::new();
    let mut got_f = Vec::new();
    for (i, req) in requests.iter().enumerate() {
        // grow and shrink while work is in flight: a resize must drain,
        // never drop
        if i == 60 {
            assert_eq!(elastic.resize_executors(3).unwrap(), 3);
        }
        if i == 180 {
            assert_eq!(elastic.resize_executors(1).unwrap(), 1);
        }
        let mut re = req.clone();
        re.arrival = Instant::now();
        let mut rf = req.clone();
        rf.arrival = Instant::now();
        got_e.push(elastic.submit(re).unwrap());
        got_f.push(fixed.submit(rf).unwrap());
    }

    for (i, (rx_e, rx_f)) in got_e.into_iter().zip(got_f).enumerate() {
        let e = rx_e.recv_timeout(RECV_TIMEOUT).expect("elastic tier dropped a request");
        let f = rx_f.recv_timeout(RECV_TIMEOUT).expect("fixed tier dropped a request");
        assert_eq!(e.id, f.id);
        let oe = e.outcome.as_ref().expect("elastic response failed");
        let of = f.outcome.as_ref().expect("fixed response failed");
        assert_eq!(oe.len(), of.len());
        for (te, tf) in oe.iter().zip(of) {
            assert_eq!(te.shape, tf.shape, "request {i}");
            assert_eq!(te.data, tf.data, "request {i}: resize changed the numerics");
        }
    }

    elastic.shutdown();
    fixed.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn zipf_traffic_heats_the_cache_where_uniform_cannot() {
    // same cache, same capacity, same row universe — only the id skew
    // differs. zipf:1.0's head must make a small cache worthwhile while
    // uniform traffic thrashes it.
    let rows = 8192u32;
    let samples = 30_000usize;
    let row = vec![0f32; 8];
    let mut rates = Vec::new();
    for skew in [IndexSkew::Uniform, IndexSkew::Zipf(1.0)] {
        let mut cache = HotRowCache::new(256, 1);
        let t = cache.register_table();
        let mut rng = Pcg32::seeded(99);
        let mut sink = Vec::new();
        for _ in 0..samples {
            sink.clear();
            let r = skew.sample(&mut rng, rows);
            if let CacheOutcome::Miss { admit: true } = cache.lookup_collect(t, r, &mut sink) {
                cache.insert(t, r, &row);
            }
        }
        rates.push(cache.counters()[t as usize].hit_rate());
    }
    let (uniform, zipf) = (rates[0], rates[1]);
    assert!(uniform < 0.10, "uniform over 8k rows cannot hit a 256-row cache: {uniform}");
    assert!(zipf > 0.25, "zipf:1.0 head should hit a 256-row cache: {zipf}");
    assert!(zipf > 4.0 * uniform, "zipf must materially beat uniform: {zipf} vs {uniform}");
}
