//! Backend parity seals: identical requests served through the
//! execution backends must agree within the per-precision tolerance
//! bound of the quant error model (`Precision::min_sqnr_db`, §3.2.2
//! technique 3).
//!
//! The native backend needs no HLO/PJRT and no `make artifacts`: these
//! tests synthesize a manifest + DCIW weights fixture (a recsys-lite
//! and a cv-lite family with native op programs) in a temp dir, so the
//! whole file runs in CI under `--no-default-features` too. The
//! PJRT-vs-native cross-check at the end additionally requires real
//! artifacts and the `pjrt` feature (skips cleanly otherwise).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dcinfer::coordinator::{stack_rows, FrontendConfig, InferRequest, ServingFrontend};
use dcinfer::models::{CvService, RecSysService};
use dcinfer::quant::error::sqnr_db;
use dcinfer::runtime::{
    synthetic_artifacts_dir, BackendSpec, ExecBackend, HostTensor, LoadedArtifact, Manifest,
    NativeBackend, Precision,
};
use dcinfer::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// Fixture: the crate's self-synthesized artifacts dir (pure Rust)
// ---------------------------------------------------------------------------

fn fixture_dir(tag: &str) -> PathBuf {
    synthetic_artifacts_dir(tag).expect("writing synthetic artifacts fixture")
}

fn run_single(art: &dyn LoadedArtifact, req: &InferRequest) -> Vec<f32> {
    let inputs = stack_rows(std::slice::from_ref(req), 1).unwrap();
    art.run(&inputs).unwrap().iter().flat_map(|t| t.as_f32().unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Native backend: every precision against the fp32 reference
// ---------------------------------------------------------------------------

#[test]
fn native_precisions_agree_within_quant_error_bounds() {
    let dir = fixture_dir("prec");
    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Pcg32::seeded(5);
    let mut dense = vec![0f32; 4 * 8];
    rng.fill_normal(&mut dense, 0.0, 1.0);
    let idx: Vec<i32> = (0..4 * 2 * 4).map(|_| rng.below(64) as i32).collect();
    let inputs = vec![
        HostTensor::from_f32(&[4, 8], &dense),
        HostTensor::from_i32(&[4, 2, 4], &idx),
    ];

    let reference = NativeBackend::new(Precision::Fp32)
        .load(&manifest, "recsys_fp32_b4")
        .unwrap()
        .run(&inputs)
        .unwrap()[0]
        .as_f32()
        .unwrap();
    for p in &reference {
        assert!(*p > 0.0 && *p < 1.0, "prob {p} outside (0,1)");
    }

    for p in [Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16] {
        let backend = NativeBackend::new(p);
        assert_eq!(backend.precision(), p);
        assert_eq!(backend.label(), format!("native/{p}"));
        let got = backend
            .load(&manifest, "recsys_fp32_b4")
            .unwrap()
            .run(&inputs)
            .unwrap()[0]
            .as_f32()
            .unwrap();
        let db = sqnr_db(&reference, &got);
        assert!(
            db >= p.min_sqnr_db(),
            "{p}: sqnr {db:.1} dB below the {:.0} dB bound",
            p.min_sqnr_db()
        );
    }
}

#[test]
fn native_cv_precisions_agree_on_conv_path() {
    let dir = fixture_dir("cvprec");
    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Pcg32::seeded(9);
    let mut image = vec![0f32; 2 * 64];
    rng.fill_normal(&mut image, 0.0, 1.0);
    let inputs = vec![HostTensor::from_f32(&[2, 1, 8, 8], &image)];

    let reference = NativeBackend::new(Precision::Fp32)
        .load(&manifest, "cv_tiny_b2")
        .unwrap()
        .run(&inputs)
        .unwrap()[0]
        .as_f32()
        .unwrap();
    for p in [Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16] {
        let got = NativeBackend::new(p)
            .load(&manifest, "cv_tiny_b2")
            .unwrap()
            .run(&inputs)
            .unwrap()[0]
            .as_f32()
            .unwrap();
        let db = sqnr_db(&reference, &got);
        assert!(db >= p.min_sqnr_db(), "{p}: conv sqnr {db:.1} dB");
    }
}

// ---------------------------------------------------------------------------
// Acceptance: mixed recsys+CV traffic on NativeBackend at i8acc16
// ---------------------------------------------------------------------------

#[test]
fn mixed_traffic_on_native_i8acc16_passes_tolerance_with_attribution() {
    let dir = fixture_dir("mixed");
    let manifest = Manifest::load(&dir).unwrap();
    let recsys = RecSysService::from_manifest(&manifest).unwrap();
    let cv = CvService::from_manifest(&manifest).unwrap();
    let spec = BackendSpec::native(Precision::I8Acc16);
    let frontend = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.clone(),
            executors: 2,
            max_wait_us: 1_000.0,
            backend: spec,
            ..Default::default()
        },
        vec![Arc::new(recsys.clone()), Arc::new(cv.clone())],
    )
    .unwrap();
    assert_eq!(frontend.backend("recsys"), Some(spec));
    assert_eq!(frontend.backend("cv"), Some(spec));

    // fp32 reference artifacts (the tolerance model's baseline)
    let fp32 = NativeBackend::new(Precision::Fp32);
    let ref_rec = fp32.load(&manifest, "recsys_fp32_b1").unwrap();
    let ref_cv = fp32.load(&manifest, "cv_tiny_b1").unwrap();

    let per_model = 20u64;
    let mut rng = Pcg32::seeded(77);
    let mut pending = Vec::new();
    for i in 0..per_model {
        let mut req = recsys.synth_request(2 * i, &mut rng, 200.0);
        let reference = run_single(ref_rec.as_ref(), &req);
        req.arrival = Instant::now();
        pending.push(("recsys", frontend.submit(req).unwrap(), reference));
        let mut req = cv.synth_request(2 * i + 1, &mut rng, 0.0);
        let reference = run_single(ref_cv.as_ref(), &req);
        req.arrival = Instant::now();
        pending.push(("cv", frontend.submit(req).unwrap(), reference));
    }

    // collect; compare aggregate per model (the statistically meaningful
    // object for an SQNR bound)
    let mut refs: std::collections::BTreeMap<&str, Vec<f32>> = Default::default();
    let mut gots: std::collections::BTreeMap<&str, Vec<f32>> = Default::default();
    for (model, rx, reference) in pending {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let rows = resp.outcome.as_ref().expect("mixed i8acc16 response ok");
        assert_eq!(resp.backend, "native/i8acc16", "response attribution");
        refs.entry(model).or_default().extend(reference);
        gots.entry(model)
            .or_default()
            .extend(rows.iter().flat_map(|t| t.as_f32().unwrap()));
    }
    for (model, reference) in &refs {
        let db = sqnr_db(reference, &gots[model]);
        assert!(
            db >= Precision::I8Acc16.min_sqnr_db(),
            "{model}: i8acc16 sqnr {db:.1} dB below bound"
        );
    }

    // per-model metrics attribute every batch to the int8 native path
    let mut total = 0u64;
    for (model, snap) in frontend.snapshot_all() {
        assert_eq!(snap.served, per_model, "{model} served {}", snap.served);
        assert_eq!(snap.failed, 0, "{model} had failures");
        assert!(
            snap.by_backend
                .iter()
                .any(|(l, _, reqs)| l == "native/i8acc16" && *reqs == per_model),
            "{model} attribution: {:?}",
            snap.by_backend
        );
        total += snap.served;
    }
    assert_eq!(total, 2 * per_model);
    frontend.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Per-model backend overrides: fp32 and int8 lanes in one frontend
// ---------------------------------------------------------------------------

#[test]
fn per_model_backend_overrides_split_pools() {
    let dir = fixture_dir("override");
    let manifest = Manifest::load(&dir).unwrap();
    let recsys = RecSysService::from_manifest(&manifest).unwrap();
    let cv = CvService::from_manifest(&manifest).unwrap();
    let fp32 = BackendSpec::native(Precision::Fp32);
    let int8 = BackendSpec::native(Precision::I8Acc32);
    let frontend = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.clone(),
            executors: 1,
            max_wait_us: 500.0,
            backend: fp32,
            model_backends: vec![("cv".to_string(), int8)],
            ..Default::default()
        },
        vec![Arc::new(recsys.clone()), Arc::new(cv.clone())],
    )
    .unwrap();
    assert_eq!(frontend.backend("recsys"), Some(fp32));
    assert_eq!(frontend.backend("cv"), Some(int8));

    let mut rng = Pcg32::seeded(11);
    let mut rec_rx = Vec::new();
    let mut cv_rx = Vec::new();
    for i in 0..6 {
        let mut r = recsys.synth_request(i, &mut rng, 200.0);
        r.arrival = Instant::now();
        rec_rx.push(frontend.submit(r).unwrap());
        let mut r = cv.synth_request(100 + i, &mut rng, 0.0);
        r.arrival = Instant::now();
        cv_rx.push(frontend.submit(r).unwrap());
    }
    for rx in rec_rx {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(resp.is_ok());
        assert_eq!(resp.backend, "native/fp32");
    }
    for rx in cv_rx {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(resp.is_ok());
        assert_eq!(resp.backend, "native/i8acc32");
    }
    let rec_snap = frontend.metrics("recsys").unwrap().snapshot();
    assert!(rec_snap.by_backend.iter().all(|(l, _, _)| l == "native/fp32"));
    let cv_snap = frontend.metrics("cv").unwrap().snapshot();
    assert!(cv_snap.by_backend.iter().all(|(l, _, _)| l == "native/i8acc32"));
    frontend.shutdown();

    // an override naming an unregistered model is a config error, not a
    // silent no-op
    let bad = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.clone(),
            backend: fp32,
            model_backends: vec![("no_such_model".to_string(), int8)],
            ..Default::default()
        },
        vec![Arc::new(recsys.clone())],
    );
    assert!(bad.is_err(), "typo'd backend override must be rejected");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// PJRT vs native on real artifacts (feature + `make artifacts` gated)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_agree_on_real_artifacts() {
    use dcinfer::runtime::PjrtBackend;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let name = "recsys_fp32_b16";
    let Ok(meta) = manifest.artifact(name) else { return };
    if !meta.has_native_program() {
        eprintln!("skipping: artifacts predate native op programs (rerun `make artifacts`)");
        return;
    }
    let rows = manifest.model_config("recsys").unwrap().get("rows_per_table").as_usize().unwrap();

    let mut rng = Pcg32::seeded(41);
    let dense_meta = &meta.inputs[0];
    let idx_meta = &meta.inputs[1];
    let mut dense = vec![0f32; dense_meta.elem_count()];
    rng.fill_normal(&mut dense, 0.0, 1.0);
    let idx: Vec<i32> =
        (0..idx_meta.elem_count()).map(|_| rng.below(rows as u32) as i32).collect();
    let inputs = vec![
        HostTensor::from_f32(&dense_meta.shape, &dense),
        HostTensor::from_i32(&idx_meta.shape, &idx),
    ];

    let pjrt = PjrtBackend::cpu().unwrap();
    let reference = pjrt.load(&manifest, name).unwrap().run(&inputs).unwrap()[0]
        .as_f32()
        .unwrap();
    for p in Precision::all() {
        let got = NativeBackend::new(p)
            .load(&manifest, name)
            .unwrap()
            .run(&inputs)
            .unwrap()[0]
            .as_f32()
            .unwrap();
        let db = sqnr_db(&reference, &got);
        assert!(db >= p.min_sqnr_db(), "native/{p} vs pjrt: sqnr {db:.1} dB");
    }
}
