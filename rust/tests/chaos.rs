//! Chaos suite: seeded fault-injection scenarios over an in-process
//! mini-fleet (two `ShardServer`s, two serving replicas, one
//! `ClusterRouter`), asserting the resilience contract end to end.
//!
//! The invariant every scenario checks: under injected faults, each
//! response is **bit-identical** to the fault-free reference, **or** a
//! typed error, **or** flagged `degraded` — never silently wrong.
//! Fault schedules come from [`dcinfer::faultnet`] plans, so whether a
//! given op faults is a pure function of the plan seed; thread
//! interleaving can shift *which* requests are affected, which is why
//! the assertions are invariant-shaped rather than per-request.
//!
//! Plans only attach to connections opened **after** installation, so
//! every scenario installs its plan before the fleet under test comes
//! up and scopes rules by peer label + `after=` so the one-time table
//! registration (a handful of ops per shard connection) passes clean.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dcinfer::cluster::{ClusterRouter, RouterConfig, ShardServer, ShardServerConfig};
use dcinfer::coordinator::{
    ClientResponse, DcClient, FrontendConfig, ModelService, ServerConfig, ServingFrontend,
    ServingServer,
};
use dcinfer::embedding::SparseTierConfig;
use dcinfer::faultnet;
use dcinfer::models::RecSysService;
use dcinfer::runtime::{synthetic_artifacts_dir, BackendSpec, Manifest, Precision};
use dcinfer::util::rng::Pcg32;

/// The fault injector is process-global; every chaos test serializes.
static SERIAL: Mutex<()> = Mutex::new(());

/// Output tensors of one response, as (shape, raw bytes) for exact
/// bit-level comparison.
type Tensors = Vec<(Vec<usize>, Vec<u8>)>;

/// What the client observed for one request.
struct Shot {
    ok: bool,
    degraded: bool,
    replica: String,
    outputs: Option<Tensors>,
}

struct Fleet {
    svc: RecSysService,
    shards: Vec<ShardServer>,
    frontends: Vec<Arc<ServingFrontend>>,
    servers: Vec<ServingServer>,
    router: ClusterRouter,
}

impl Fleet {
    /// Two shard servers, two serving replicas over them, one router.
    /// `pre_router` runs after the replicas are bound but before the
    /// router connects to them — the hook scenarios use to install
    /// plans that target a specific `router->ADDR` peer label.
    fn start(dir: &Path, replication: usize, pre_router: impl FnOnce(&[String])) -> Fleet {
        let manifest = Manifest::load(dir).expect("manifest");
        let svc = RecSysService::from_manifest(&manifest).expect("recsys config");
        let shards: Vec<ShardServer> = (0..2)
            .map(|_| {
                ShardServer::bind("127.0.0.1:0", ShardServerConfig::default())
                    .expect("shard bind")
            })
            .collect();
        let shard_addrs: Vec<String> = shards.iter().map(|s| s.local_addr().to_string()).collect();
        let mut frontends = Vec::new();
        let mut servers = Vec::new();
        for r in 0..2 {
            let services: Vec<Arc<dyn ModelService>> = vec![Arc::new(svc.clone())];
            let frontend = Arc::new(
                ServingFrontend::start(
                    FrontendConfig {
                        artifacts_dir: dir.to_path_buf(),
                        executors: 1,
                        backend: BackendSpec::native(Precision::Fp32),
                        sparse_tier: Some(SparseTierConfig {
                            shards: 2,
                            replication,
                            // cache off: degraded serving falls back to
                            // zero rows, and exact runs never diverge
                            // through cache state
                            cache_capacity_rows: 0,
                            remote_shards: shard_addrs.clone(),
                            ..Default::default()
                        }),
                        ..Default::default()
                    },
                    services,
                )
                .expect("frontend start"),
            );
            let server = ServingServer::bind(
                frontend.clone(),
                "127.0.0.1:0",
                ServerConfig { replica_label: format!("replica-{r}"), ..Default::default() },
            )
            .expect("server bind");
            frontends.push(frontend);
            servers.push(server);
        }
        let replica_addrs: Vec<String> =
            servers.iter().map(|s| s.local_addr().to_string()).collect();
        pre_router(&replica_addrs);
        let router = ClusterRouter::bind("127.0.0.1:0", &replica_addrs, RouterConfig::default())
            .expect("router bind");
        let fleet = Fleet { svc, shards, frontends, servers, router };
        // warm: flushes one-time table registration to the shards and
        // settles router health, so measured shots see a steady fleet
        let _ = run_load(&fleet, 6, 400.0, 0xEEEE);
        fleet
    }

    fn shutdown(&self) {
        self.router.shutdown();
        for s in &self.servers {
            s.shutdown();
        }
        for f in &self.frontends {
            f.shutdown();
        }
        for s in &self.shards {
            s.shutdown();
        }
    }

    /// Tier failovers summed across both replicas' sparse tiers.
    fn tier_failovers(&self) -> u64 {
        self.frontends
            .iter()
            .filter_map(|f| f.sparse_tier())
            .map(|t| t.snapshot().failovers)
            .sum()
    }

    /// Degraded lookups summed across both replicas' sparse tiers.
    fn tier_degraded(&self) -> u64 {
        self.frontends
            .iter()
            .filter_map(|f| f.sparse_tier())
            .map(|t| t.snapshot().degraded_lookups)
            .sum()
    }
}

/// Open-loop recsys load through the router. `(n, qps, seed)` fully
/// determine the request stream, so a reference run and a fault run
/// with the same triple submit bit-identical requests.
fn run_load(fleet: &Fleet, n: u64, qps: f64, seed: u64) -> Vec<Shot> {
    let client = DcClient::connect(fleet.router.local_addr()).expect("connect");
    let mut rng = Pcg32::seeded(seed);
    let mut pending = Vec::with_capacity(n as usize);
    let t0 = Instant::now();
    let mut next_at = 0.0f64;
    for i in 0..n {
        next_at += rng.exponential(qps);
        let now = t0.elapsed().as_secs_f64();
        if next_at > now {
            std::thread::sleep(Duration::from_secs_f64(next_at - now));
        }
        let req = fleet.svc.synth_request(seed * 1_000_000 + i, &mut rng, 10_000.0);
        pending.push(client.submit(&req).ok());
    }
    let shots = pending
        .into_iter()
        .map(|rx| {
            let failed = Shot { ok: false, degraded: false, replica: String::new(), outputs: None };
            let Some(rx) = rx else { return failed };
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(cr) => shot_of(cr),
                Err(_) => failed,
            }
        })
        .collect();
    client.close();
    shots
}

fn shot_of(cr: ClientResponse) -> Shot {
    match &cr.resp.outcome {
        Ok(tensors) if !cr.shed() => Shot {
            ok: true,
            degraded: cr.resp.degraded,
            replica: cr.resp.replica.clone(),
            outputs: Some(tensors.iter().map(|t| (t.shape.clone(), t.data.clone())).collect()),
        },
        _ => Shot { ok: false, degraded: false, replica: cr.resp.replica.clone(), outputs: None },
    }
}

/// The fault-free reference: same fleet shape, no plan installed.
/// Every reference request must be served clean — if this fails the
/// scenario's comparison would be meaningless.
fn reference_shots(dir: &Path, replication: usize, n: u64, qps: f64, seed: u64) -> Vec<Shot> {
    faultnet::clear();
    let fleet = Fleet::start(dir, replication, |_| {});
    let shots = run_load(&fleet, n, qps, seed);
    fleet.shutdown();
    for (i, s) in shots.iter().enumerate() {
        assert!(s.ok && !s.degraded, "fault-free reference request {i} was not served clean");
    }
    shots
}

/// The chaos invariant: each observed response is bit-identical to the
/// reference, a typed error, or flagged degraded. Returns
/// `(exact, degraded, errors)` for scenario-specific rate assertions.
fn assert_faithful(reference: &[Shot], observed: &[Shot]) -> (u64, u64, u64) {
    assert_eq!(reference.len(), observed.len());
    let (mut exact, mut degraded, mut errors) = (0u64, 0u64, 0u64);
    for (i, (r, o)) in reference.iter().zip(observed).enumerate() {
        if !o.ok {
            errors += 1;
            continue;
        }
        if o.degraded {
            degraded += 1;
            continue;
        }
        assert_eq!(
            o.outputs, r.outputs,
            "request {i}: an ok, non-degraded response under faults must be \
             bit-identical to the fault-free reference"
        );
        exact += 1;
    }
    (exact, degraded, errors)
}

/// Scenario 1: connections to the remote shards reset mid-lookup.
/// Replication 2 means every row range has a second replica, so the
/// tier fails over and answers stay exact; goodput holds.
#[test]
fn resets_mid_lookup_fail_over_bit_identically() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("chaos_reset").expect("fixture");
    let (n, qps, seed) = (200u64, 500.0, 0xA11CE);
    let reference = reference_shots(&dir, 2, n, qps, seed);

    // after=64 lets per-connection registration traffic through; every
    // reconnect restarts the op count, so resets recur all run long
    faultnet::install_spec("seed=11;reset,peer=rshard,dir=write,after=64,every=24").unwrap();
    let fleet = Fleet::start(&dir, 2, |_| {});
    let shots = run_load(&fleet, n, qps, seed);
    faultnet::clear();
    let failovers = fleet.tier_failovers();
    fleet.shutdown();

    let (exact, degraded, errors) = assert_faithful(&reference, &shots);
    assert!(failovers > 0, "resets never exercised shard failover");
    assert!(
        exact + degraded >= n * 9 / 10,
        "goodput collapsed under shard resets: {exact} exact + {degraded} degraded \
         + {errors} errors / {n}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scenario 2: shard response frames arrive with a flipped bit. The
/// frame checksum must catch every corruption — a corrupted frame may
/// cost a failover, never a silently wrong answer.
#[test]
fn corrupted_shard_frames_surface_as_typed_errors_never_wrong_bits() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("chaos_corrupt").expect("fixture");
    let (n, qps, seed) = (200u64, 500.0, 0xBEEF);
    let reference = reference_shots(&dir, 2, n, qps, seed);

    faultnet::install_spec("seed=7;corrupt,peer=rshard,dir=read,every=97").unwrap();
    let fleet = Fleet::start(&dir, 2, |_| {});
    let shots = run_load(&fleet, n, qps, seed);
    faultnet::clear();
    fleet.shutdown();

    let (exact, degraded, errors) = assert_faithful(&reference, &shots);
    assert!(exact > 0);
    assert!(
        exact + degraded >= n * 9 / 10,
        "goodput collapsed under frame corruption: {exact} exact + {degraded} degraded \
         + {errors} errors / {n}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scenario 3: one serving replica turns slow (every read on the
/// router's leg to it is delayed past the probe latency bound). The
/// router must classify it Suspect/unroutable and steer traffic to the
/// healthy replica; once the fault window closes, the replica recovers
/// and serves exact answers again.
#[test]
fn slow_replica_is_suspected_rerouted_and_recovers() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("chaos_slow").expect("fixture");
    let (n, qps, seed) = (120u64, 400.0, 0x510);
    let reference = reference_shots(&dir, 2, n, qps, seed);

    let mut installed = Instant::now();
    let fleet = Fleet::start(&dir, 2, |replica_addrs| {
        // delay only the router's leg to replica 0, reads, for a 4 s
        // window from installation — well past the 250 ms probe bound
        let spec =
            format!("seed=3;delay,peer=router->{},dir=read,ms=300,for_ms=4000", replica_addrs[0]);
        faultnet::install_spec(&spec).unwrap();
        installed = Instant::now();
    });

    let saw_suspect = AtomicBool::new(false);
    let shots = std::thread::scope(|s| {
        s.spawn(|| {
            for _ in 0..80 {
                let stats = fleet.router.stats();
                if stats.iter().any(|r| r.suspect || !r.healthy) {
                    saw_suspect.store(true, Ordering::SeqCst);
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        });
        run_load(&fleet, n, qps, seed)
    });
    assert!(
        saw_suspect.load(Ordering::SeqCst),
        "a replica answering probes 300 ms late was never marked suspect/unroutable"
    );
    // responses during the window: rerouted (exact), late (exact), or
    // casualties of a recycled replica connection (typed errors)
    let (_, _, window_errors) = assert_faithful(&reference, &shots);
    assert!(
        window_errors <= n / 4,
        "rerouting around a slow replica lost too much: {window_errors} errors / {n}"
    );

    // let the window close and the prober take a clean lap
    let settle = installed + Duration::from_millis(4000 + 1000);
    if let Some(wait) = settle.checked_duration_since(Instant::now()) {
        std::thread::sleep(wait);
    }
    for r in fleet.router.stats() {
        assert!(r.healthy && !r.suspect, "replica {} did not recover: {r:?}", r.addr);
    }
    faultnet::clear();
    let shots2 = run_load(&fleet, n, qps, seed);
    let (exact2, degraded2, errors2) = assert_faithful(&reference, &shots2);
    assert_eq!(
        (exact2, degraded2, errors2),
        (n, 0, 0),
        "post-recovery load must be entirely exact"
    );
    assert!(
        shots2.iter().any(|s| s.replica == "replica-0"),
        "the recovered replica never served again"
    );
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scenario 4: a full row-range outage — every shard server goes down,
/// so no replica of any range is reachable. The tier serves degraded
/// (zero-row contributions, flagged) instead of failing, and goodput
/// stays within 90% of fault-free.
#[test]
fn full_range_outage_serves_degraded_and_keeps_goodput() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultnet::clear();
    let dir = synthetic_artifacts_dir("chaos_outage").expect("fixture");
    let (n, qps, seed) = (160u64, 500.0, 0xDEAD);
    let reference = reference_shots(&dir, 1, n, qps, seed);

    let fleet = Fleet::start(&dir, 1, |_| {});
    // registration flushed by the warm load inside start; now take the
    // whole shard fleet down
    for s in &fleet.shards {
        s.shutdown();
    }
    let shots = run_load(&fleet, n, qps, seed);
    let tier_degraded = fleet.tier_degraded();
    fleet.shutdown();

    let (exact, degraded, errors) = assert_faithful(&reference, &shots);
    assert!(degraded > 0, "a full outage must surface flagged degraded responses");
    assert!(tier_degraded > 0, "the tier never counted a degraded lookup");
    // acceptance: goodput under the outage >= 90% of fault-free (the
    // reference served all n) — degraded answers are served answers
    assert!(
        exact + degraded >= n * 9 / 10,
        "degraded serving did not hold goodput: {exact} exact + {degraded} degraded \
         + {errors} errors / {n}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scenario 5: a flapping shard peer — connections die and come back
/// every few dozen ops, both directions. Failover plus breaker
/// deprioritization keep the answers exact-or-flagged and goodput up.
#[test]
fn flapping_shard_peer_churns_without_silent_corruption() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("chaos_flap").expect("fixture");
    let (n, qps, seed) = (200u64, 500.0, 0xF1AB);
    let reference = reference_shots(&dir, 2, n, qps, seed);

    faultnet::install_spec("seed=13;reset,peer=rshard,after=64,every=25").unwrap();
    let fleet = Fleet::start(&dir, 2, |_| {});
    let shots = run_load(&fleet, n, qps, seed);
    faultnet::clear();
    let failovers = fleet.tier_failovers();
    fleet.shutdown();

    let (exact, degraded, errors) = assert_faithful(&reference, &shots);
    assert!(failovers > 0, "a flapping peer never exercised failover");
    assert!(
        exact + degraded >= n * 9 / 10,
        "goodput collapsed under a flapping peer: {exact} exact + {degraded} degraded \
         + {errors} errors / {n}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scenario 6: every router link is throttled to a 256-byte trickle.
/// Pure slowness must not cost correctness: every response exact, no
/// errors, no degradation.
#[test]
fn throttled_router_links_stay_bit_exact() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("chaos_throttle").expect("fixture");
    let (n, qps, seed) = (140u64, 400.0, 0x7407);
    let reference = reference_shots(&dir, 2, n, qps, seed);

    faultnet::install_spec("seed=3;throttle,peer=router,chunk=256,us=50").unwrap();
    let fleet = Fleet::start(&dir, 2, |_| {});
    let shots = run_load(&fleet, n, qps, seed);
    faultnet::clear();
    fleet.shutdown();

    let (exact, degraded, errors) = assert_faithful(&reference, &shots);
    assert_eq!(
        (exact, degraded, errors),
        (n, 0, 0),
        "throttling is not allowed to cost correctness"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scenario 7: the client's own uplink breaks mid-frame (partial write
/// then a broken pipe). The server side misframes and drops the
/// connection; everything in flight surfaces as a typed error, and
/// everything served before the break is exact.
#[test]
fn partial_client_writes_surface_typed_errors() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("chaos_partial").expect("fixture");
    let (n, qps, seed) = (120u64, 400.0, 0xBAD5EED);
    let reference = reference_shots(&dir, 2, n, qps, seed);

    faultnet::install_spec("seed=21;partial,peer=client->,dir=write,after=12,every=31").unwrap();
    let fleet = Fleet::start(&dir, 2, |_| {});
    let shots = run_load(&fleet, n, qps, seed);
    faultnet::clear();
    fleet.shutdown();

    let (exact, degraded, errors) = assert_faithful(&reference, &shots);
    assert!(exact > 0, "nothing was served before the uplink broke");
    assert!(errors > 0, "the mid-frame break never surfaced");
    assert_eq!(degraded, 0);
    let _ = std::fs::remove_dir_all(&dir);
}
