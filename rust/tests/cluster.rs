//! Cluster-plane integration: a loopback mini-fleet of *real
//! processes* — `dcinfer shard-serve` shard servers and `dcinfer serve
//! --listen` replicas spawned via `CARGO_BIN_EXE_dcinfer` — behind an
//! in-process `ClusterRouter`, with failures injected by killing
//! processes mid-load.
//!
//! The acceptance property: a killed serving replica and a killed
//! shard process each cost at most a few typed errors, never a wrong
//! answer — every successful response stays bit-identical to an
//! in-process monolithic frontend on the same deterministic fixture,
//! and goodput recovers on the survivors.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dcinfer::cluster::{ChildProc, ClusterRouter, RouterConfig};
use dcinfer::coordinator::{
    ClientResponse, DcClient, FrontendConfig, InferError, InferRequest, ModelService,
    ServingFrontend,
};
use dcinfer::models::RecSysService;
use dcinfer::runtime::{synthetic_artifacts_dir, BackendSpec, HostTensor, Manifest, Precision};
use dcinfer::util::rng::Pcg32;

// a mini-fleet is several processes worth of executor threads;
// serialize the tests so timing-sensitive behaviour stays stable
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_dcinfer"))
}

fn assert_bit_identical(got: &[HostTensor], want: &[HostTensor], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: output count");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.dtype, w.dtype, "{what}: dtype");
        assert_eq!(g.shape, w.shape, "{what}: shape");
        assert_eq!(g.data, w.data, "{what}: bytes differ — a wrong answer, not an error");
    }
}

/// The placement-invariance oracle: the same fixture served by one
/// in-process frontend with no sparse tier at all.
struct Reference {
    frontend: Arc<ServingFrontend>,
}

impl Reference {
    fn start(dir: &PathBuf, recsys: &RecSysService) -> Reference {
        let services: Vec<Arc<dyn ModelService>> = vec![Arc::new(recsys.clone())];
        let frontend = Arc::new(
            ServingFrontend::start(
                FrontendConfig {
                    artifacts_dir: dir.clone(),
                    executors: 1,
                    backend: BackendSpec::native(Precision::Fp32),
                    ..Default::default()
                },
                services,
            )
            .expect("reference frontend start"),
        );
        Reference { frontend }
    }

    fn expected(&self, req: &InferRequest) -> Vec<HostTensor> {
        let mut r = req.clone();
        r.arrival = Instant::now();
        let rx = self.frontend.submit(r).expect("reference submit");
        rx.recv_timeout(Duration::from_secs(60))
            .expect("reference response")
            .outcome
            .expect("reference serves every request")
    }
}

#[test]
fn fleet_survives_replica_and_shard_kills_with_zero_wrong_answers() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("cluster_kill").expect("fixture");
    let manifest = Manifest::load(&dir).expect("manifest");
    let recsys = RecSysService::from_manifest(&manifest).expect("recsys config");
    let reference = Reference::start(&dir, &recsys);

    // 2 shard processes at replication 2: one row range, two replicas —
    // killing either shard leaves every row reachable
    let mut shards: Vec<ChildProc> = (0..2)
        .map(|s| {
            ChildProc::spawn(
                &bin(),
                &["shard-serve", "--listen", "127.0.0.1:0"],
                &format!("shard-{s}"),
            )
            .expect("spawn shard server")
        })
        .collect();
    let shard_addrs = shards.iter().map(|c| c.addr.clone()).collect::<Vec<_>>().join(",");

    // 2 serving replicas, both wired to the same remote shard fleet
    let art = dir.to_string_lossy().to_string();
    let mut replicas: Vec<ChildProc> = (0..2)
        .map(|r| {
            let label = format!("replica-{r}");
            ChildProc::spawn(
                &bin(),
                &[
                    "serve",
                    "--listen",
                    "127.0.0.1:0",
                    "--models",
                    "recsys",
                    "--artifacts",
                    &art,
                    "--backend",
                    "native",
                    "--replica-label",
                    &label,
                    "--sparse-shards",
                    "2",
                    "--sparse-replication",
                    "2",
                    "--remote-shards",
                    &shard_addrs,
                ],
                &label,
            )
            .expect("spawn serving replica")
        })
        .collect();
    let replica_addrs: Vec<String> = replicas.iter().map(|c| c.addr.clone()).collect();

    let router =
        ClusterRouter::bind("127.0.0.1:0", &replica_addrs, RouterConfig::default())
            .expect("router bind");
    let client = DcClient::connect(router.local_addr()).expect("connect through router");
    let mut rng = Pcg32::seeded(777);

    // paced submissions: mid-load kills land between frames, not only
    // between phases
    let send = |client: &DcClient,
                    rng: &mut Pcg32,
                    lo: u64,
                    n: u64|
     -> Vec<(InferRequest, Receiver<ClientResponse>)> {
        (lo..lo + n)
            .map(|i| {
                let req = recsys.synth_request(i, rng, 10_000.0);
                let rx = client.submit(&req).expect("submit through router");
                std::thread::sleep(Duration::from_millis(2));
                (req, rx)
            })
            .collect()
    };

    // --- phase A: healthy fleet — everything ok, bit-identical -----------
    let phase_a = send(&client, &mut rng, 0, 40);
    let mut replicas_seen: BTreeSet<String> = BTreeSet::new();
    for (req, rx) in phase_a {
        let cr = rx.recv_timeout(Duration::from_secs(60)).expect("healthy fleet answers");
        let outs = cr.resp.outcome.as_ref().expect("healthy fleet serves everything");
        assert_bit_identical(outs, &reference.expected(&req), "phase A");
        assert!(
            !cr.resp.replica.is_empty(),
            "fleet responses carry the replica label for attribution"
        );
        replicas_seen.insert(cr.resp.replica.clone());
    }
    assert!(!replicas_seen.is_empty());
    assert_eq!(router.healthy_replicas(), 2);

    // --- phase B: kill replica-0 mid-load --------------------------------
    let b1 = send(&client, &mut rng, 1_000, 15);
    replicas[0].kill();
    let b2 = send(&client, &mut rng, 2_000, 45);
    let (mut ok_b, mut err_b) = (0u64, 0u64);
    for (req, rx) in b1 {
        let cr = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("every request answered across a replica kill");
        match &cr.resp.outcome {
            Ok(outs) => {
                assert_bit_identical(outs, &reference.expected(&req), "phase B (pre-kill)");
                ok_b += 1;
            }
            Err(InferError::Shutdown) | Err(InferError::ExecFailed(_)) => err_b += 1,
            Err(other) => panic!("unexpected error after replica kill: {other:?}"),
        }
    }
    for (req, rx) in b2 {
        let cr = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("every request answered across a replica kill");
        match &cr.resp.outcome {
            Ok(outs) => {
                assert_bit_identical(outs, &reference.expected(&req), "phase B (post-kill)");
                assert_eq!(
                    cr.resp.replica, "replica-1",
                    "only the survivor can answer after the kill"
                );
                ok_b += 1;
            }
            Err(InferError::Shutdown) | Err(InferError::ExecFailed(_)) => err_b += 1,
            Err(other) => panic!("unexpected error after replica kill: {other:?}"),
        }
    }
    assert!(ok_b >= 45, "goodput must recover after a replica kill ({ok_b} ok, {err_b} errors)");
    // the router notices the death
    let t0 = Instant::now();
    while router.healthy_replicas() != 1 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(router.healthy_replicas(), 1, "the killed replica must read as unhealthy");

    // --- phase C: kill shard-0 mid-load ----------------------------------
    // the surviving replica's sparse tier fails over to the shard's
    // replica process; failover is inside the lookup path, so requests
    // keep succeeding — and stay bit-identical
    let c1 = send(&client, &mut rng, 3_000, 15);
    shards[0].kill();
    let c2 = send(&client, &mut rng, 4_000, 45);
    let (mut ok_c, mut err_c) = (0u64, 0u64);
    for (req, rx) in c1.into_iter().chain(c2) {
        let cr = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("every request answered across a shard kill");
        match &cr.resp.outcome {
            Ok(outs) => {
                assert_bit_identical(outs, &reference.expected(&req), "phase C");
                assert_eq!(cr.resp.replica, "replica-1");
                ok_c += 1;
            }
            Err(InferError::Shutdown) | Err(InferError::ExecFailed(_)) => err_c += 1,
            Err(other) => panic!("unexpected error after shard kill: {other:?}"),
        }
    }
    assert!(
        ok_c >= 58,
        "shard failover should be transparent to the serving path ({ok_c} ok, {err_c} errors)"
    );

    // --- drain ------------------------------------------------------------
    assert_eq!(client.in_flight(), 0);
    client.close();
    router.shutdown();
    drop(replicas);
    drop(shards);
    reference.frontend.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn router_drain_loses_no_inflight_responses() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("cluster_drain").expect("fixture");
    let manifest = Manifest::load(&dir).expect("manifest");
    let recsys = RecSysService::from_manifest(&manifest).expect("recsys config");
    let art = dir.to_string_lossy().to_string();

    // one monolithic replica (no shard fleet) is enough to exercise the
    // router's drain barrier
    let replica = ChildProc::spawn(
        &bin(),
        &[
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--models",
            "recsys",
            "--artifacts",
            &art,
            "--backend",
            "native",
            "--replica-label",
            "replica-0",
        ],
        "replica-0",
    )
    .expect("spawn serving replica");
    let router = ClusterRouter::bind(
        "127.0.0.1:0",
        &[replica.addr.clone()],
        RouterConfig::default(),
    )
    .expect("router bind");
    let client = DcClient::connect(router.local_addr()).expect("connect through router");
    let mut rng = Pcg32::seeded(4242);

    let receivers: Vec<_> = (0..30u64)
        .map(|i| client.submit(&recsys.synth_request(i, &mut rng, 10_000.0)).unwrap())
        .collect();
    // let the burst reach the replica, then drain mid-flight
    std::thread::sleep(Duration::from_millis(300));
    router.shutdown();

    // every in-flight request still gets its real response through the
    // drain — the router forwards them before closing client sockets
    for rx in receivers {
        let cr = rx.recv_timeout(Duration::from_secs(60)).expect("no lost responses");
        assert!(cr.resp.is_ok(), "in-flight request lost in drain: {:?}", cr.resp.outcome);
        assert_eq!(cr.resp.replica, "replica-0");
    }
    client.close();
    drop(replica);
    let _ = std::fs::remove_dir_all(&dir);
}
