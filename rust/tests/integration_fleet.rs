//! Cross-module integration: model zoo -> characterization -> roofline
//! -> fleet simulation -> fusion mining, end to end (no artifacts
//! needed — this is the analytical half of the system).

use dcinfer::fleet::{simulate_fleet, FleetConfig};
use dcinfer::graph::{mine_frequent_subgraphs, rank_opportunities, Net};
use dcinfer::models::{representative_zoo, Category};
use dcinfer::perfmodel::roofline::fig3_capacities;
use dcinfer::perfmodel::{characterize_zoo, roofline_curve, DeviceSpec};

#[test]
fn table1_to_fig3_pipeline() {
    // characterize the zoo, then verify the roofline study is coherent
    // with the characterization: low-intensity models saturate far
    // below high-intensity ones at the same device.
    let zoo = representative_zoo();
    let models: Vec<_> = zoo.iter().map(|e| e.desc.clone()).collect();
    let rows = characterize_zoo(&models);
    let caps = fig3_capacities();

    for (m, row) in models.iter().zip(&rows) {
        let curve = roofline_curve(m, &caps, 10.0);
        let peak_achieved = curve.iter().map(|(_, v)| *v).fold(0.0, f64::max);
        if row.intensity_w_avg < 5.0 {
            assert!(peak_achieved < 40.0, "{}: low intensity but {peak_achieved} TOP/s", m.name);
        }
        if row.category == Category::ComputerVision
            && row.params < 100_000_000
            && row.intensity_full_min > 10.0
        {
            // classification trunks (no bandwidth-starved layers) get
            // close to the compute roof once weights fit on-chip;
            // detection/video models stay activation-bound (§2.2)
            assert!(peak_achieved > 20.0, "{}: {peak_achieved}", m.name);
        }
    }
}

#[test]
fn fleet_sim_to_fusion_pipeline() {
    // Fig 4 -> §3.3: the buckets the simulator flags as overhead-heavy
    // are the ones the miner surfaces as fusion opportunities.
    let zoo = representative_zoo();
    let dev = DeviceSpec::xeon_fp32();
    let agent = simulate_fleet(&zoo, &dev, &FleetConfig { requests: 500, ..Default::default() });
    let b = agent.breakdown();
    assert!(b.share("FC") > 0.2);

    let nets: Vec<(Net, f64)> =
        zoo.iter().map(|e| (Net::from_model(&e.desc, 4), e.fleet_weight * 100.0)).collect();
    let mined = mine_frequent_subgraphs(&nets, 2, 0.1);
    let top = rank_opportunities(&mined, &dev, 5);
    assert_eq!(top.len(), 5);
    // the top opportunities involve elementwise/tensor-manip consumers
    assert!(
        top.iter().any(|o| o.signature.contains("Elementwise")
            || o.signature.contains("TensorManip")),
        "{:?}",
        top.iter().map(|o| &o.signature).collect::<Vec<_>>()
    );
}

#[test]
fn observer_records_are_internally_consistent() {
    let zoo = representative_zoo();
    let dev = DeviceSpec::xeon_fp32();
    let agent = simulate_fleet(&zoo, &dev, &FleetConfig { requests: 300, ..Default::default() });
    let b = agent.breakdown();
    let share_sum: f64 = b.buckets.values().map(|v| v.1).sum();
    assert!((share_sum - 1.0).abs() < 1e-9);
    let time_sum: f64 = b.buckets.values().map(|v| v.0).sum();
    assert!((time_sum - b.total_us).abs() < 1e-6 * b.total_us);
    // inefficiency is >= ~1 for every bucket (wall >= roofline floor)
    for (bucket, ineff) in agent.inefficiency_by_bucket() {
        assert!(ineff >= 0.99, "{bucket}: {ineff}");
    }
}
