//! Cross-language correctness seal: replay the JAX-evaluated golden
//! inputs through the Rust PJRT runtime and assert the outputs match.
//!
//! Requires `make artifacts` (skips cleanly otherwise) and the `pjrt`
//! feature (the whole file drives the XLA engine; the native backend's
//! equivalent seal is `tests/backend_parity.rs`).

#![cfg(feature = "pjrt")]

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use dcinfer::runtime::{read_weights_file, Engine, HostTensor, Manifest};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn goldens(dir: &Path) -> HashMap<String, HostTensor> {
    read_weights_file(&dir.join("goldens.bin"))
        .expect("goldens.bin")
        .into_iter()
        .map(|t| (t.name, t.tensor))
        .collect()
}

fn assert_close(name: &str, got: &HostTensor, want: &HostTensor, tol: f32) {
    assert_eq!(got.shape, want.shape, "{name} shape");
    assert_eq!(got.dtype, want.dtype, "{name} dtype");
    let g = got.as_f32().unwrap();
    let w = want.as_f32().unwrap();
    let mut max_err = 0f32;
    for (a, b) in g.iter().zip(&w) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err <= tol, "{name}: max abs err {max_err} > {tol}");
}

/// Run one artifact against its goldens.
fn check_artifact(engine: &Engine, manifest: &Manifest, g: &HashMap<String, HostTensor>, name: &str, tol: f32) {
    let model = engine.load(manifest, name).expect("load");
    let n_in = model.meta.inputs.len();
    let inputs: Vec<HostTensor> =
        (0..n_in).map(|i| g[&format!("{name}/in{i}")].clone()).collect();
    let outs = model.run(engine, &inputs).expect("run");
    assert_eq!(outs.len(), model.meta.outputs.len());
    for (i, out) in outs.iter().enumerate() {
        assert_close(&format!("{name}/out{i}"), out, &g[&format!("{name}/out{i}")], tol);
    }
}

#[test]
fn recsys_fp32_matches_jax_goldens() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let g = goldens(&dir);
    for b in [1usize, 4, 16, 64] {
        let name = format!("recsys_fp32_b{b}");
        if manifest.artifacts.contains_key(&name) {
            check_artifact(&engine, &manifest, &g, &name, 2e-5);
        }
    }
}

#[test]
fn recsys_int8_matches_jax_goldens() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    if !manifest.artifacts.contains_key("recsys_int8_b16") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let g = goldens(&dir);
    check_artifact(&engine, &manifest, &g, "recsys_int8_b16", 2e-5);
}

#[test]
fn gru_step_matches_jax_goldens() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    if !manifest.artifacts.contains_key("gru_step_b1") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let g = goldens(&dir);
    check_artifact(&engine, &manifest, &g, "gru_step_b1", 5e-5);
    check_artifact(&engine, &manifest, &g, "gru_step_b8", 5e-5);
}

#[test]
fn kernel_artifacts_match_jax_goldens() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    if !manifest.artifacts.contains_key("kernel_qgemm") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let g = goldens(&dir);
    check_artifact(&engine, &manifest, &g, "kernel_qgemm", 1e-4);
    check_artifact(&engine, &manifest, &g, "kernel_sls", 2e-5);
}

#[test]
fn rejects_malformed_inputs() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let model = engine.load(&manifest, "recsys_fp32_b1").unwrap();
    // wrong arity
    assert!(model.run(&engine, &[]).is_err());
    // wrong shape
    let bad = vec![
        HostTensor::from_f32(&[1, 3], &[0.0, 0.0, 0.0]),
        HostTensor::from_i32(&[1, 8, 32], &vec![0; 256]),
    ];
    assert!(model.run(&engine, &bad).is_err());
    // wrong dtype
    let meta0 = model.meta.inputs[0].clone();
    let bad2 = vec![
        HostTensor::from_i32(&meta0.shape, &vec![0; meta0.elem_count()]),
        HostTensor::from_i32(&model.meta.inputs[1].shape, &vec![0; model.meta.inputs[1].elem_count()]),
    ];
    assert!(model.run(&engine, &bad2).is_err());
}

#[test]
fn executor_pool_round_trip() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let pool = dcinfer::runtime::ExecutorPool::new(
        2,
        dcinfer::runtime::BackendSpec::Pjrt,
        dir.clone(),
        vec!["recsys_fp32_b1".to_string()],
    )
    .unwrap();
    assert_eq!(pool.pick().backend, "pjrt/fp32");
    let g = goldens(&dir);
    let inputs = vec![
        g["recsys_fp32_b1/in0"].clone(),
        g["recsys_fp32_b1/in1"].clone(),
    ];
    // exercise both executors
    let mut outs = Vec::new();
    for _ in 0..4 {
        let resp = pool.pick().run("recsys_fp32_b1", inputs.clone()).unwrap();
        outs.push(resp.outputs[0].clone());
    }
    for o in &outs {
        assert_close("pool/out0", o, &g["recsys_fp32_b1/out0"], 2e-5);
    }
    // unknown model errors, pool survives
    assert!(pool.pick().run("nope", inputs).is_err());
    pool.shutdown();
}
