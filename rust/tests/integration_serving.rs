//! End-to-end serving integration: the full frontend (router ->
//! per-model dynamic batchers -> PJRT executors) serving the model
//! families through the `ModelService` API — including mixed recsys +
//! NMT + CV traffic against one frontend.
//!
//! Requires `make artifacts` (skips cleanly otherwise).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use dcinfer::coordinator::{FrontendConfig, ModelService, ServingFrontend};
use dcinfer::models::{CvService, NmtService, RecSysService};
use dcinfer::runtime::Manifest;
use dcinfer::util::rng::Pcg32;

// The serving tests saturate the CPU (PJRT executors + batcher threads);
// run them serially so timing-sensitive batching behaviour is stable.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn start_recsys(dir: &Path, executors: usize, max_wait_us: f64) -> (ServingFrontend, RecSysService) {
    let manifest = Manifest::load(dir).unwrap();
    let service = RecSysService::from_manifest(&manifest).unwrap();
    let frontend = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.to_path_buf(),
            executors,
            max_wait_us,
            ..Default::default()
        },
        vec![Arc::new(service.clone())],
    )
    .unwrap();
    (frontend, service)
}

#[test]
fn frontend_serves_batched_requests() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let (frontend, service) = start_recsys(&dir, 2, 1_000.0);
    let mut rng = Pcg32::seeded(100);

    // burst of 40 requests -> should form multi-request batches.
    // Pre-generate so the submit loop is pure channel sends (request
    // synthesis is slow in debug builds and would serialize the burst).
    let reqs: Vec<_> = (0..40).map(|i| service.synth_request(i, &mut rng, 200.0)).collect();
    let receivers: Vec<_> = reqs
        .into_iter()
        .map(|mut r| {
            r.arrival = Instant::now(); // stamp at submit, not generation
            frontend.submit(r).unwrap()
        })
        .collect();

    let mut max_batch = 0usize;
    for rx in receivers {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let prob = resp.scalar_f32().expect("successful recsys response");
        assert!(prob > 0.0 && prob < 1.0, "prob {prob}");
        max_batch = max_batch.max(resp.batch_size);
    }
    let snap = frontend.metrics(RecSysService::MODEL_ID).unwrap().snapshot();
    assert_eq!(snap.served, 40);
    assert_eq!(snap.failed, 0);
    if !cfg!(debug_assertions) {
        assert!(max_batch > 1, "burst never batched (max batch {max_batch})");
        assert!(snap.batches < 40, "{} batches for 40 requests", snap.batches);
    }
    frontend.shutdown();
}

#[test]
fn frontend_responses_match_single_request_path() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        return;
    };
    // serve the same request twice: once alone, once inside a burst —
    // the prediction must be identical (batching is semantically
    // transparent).
    let (frontend, service) = start_recsys(&dir, 1, 500.0);
    let mut rng = Pcg32::seeded(200);
    let probe = service.synth_request(999, &mut rng, 200.0);

    let solo = frontend.submit(probe.clone()).unwrap().recv().unwrap();
    let solo_prob = solo.scalar_f32().expect("solo response ok");

    let extra: Vec<_> = (0..15).map(|i| service.synth_request(i, &mut rng, 200.0)).collect();
    let mut probe2 = probe.clone();
    probe2.arrival = Instant::now();
    let mut receivers = vec![frontend.submit(probe2).unwrap()];
    for mut r in extra {
        r.arrival = Instant::now();
        receivers.push(frontend.submit(r).unwrap());
    }
    let burst = receivers.remove(0).recv().unwrap();
    let burst_prob = burst.scalar_f32().expect("batched response ok");
    assert!(
        (solo_prob - burst_prob).abs() < 1e-5,
        "solo {solo_prob} vs batched {burst_prob}"
    );
    for rx in receivers {
        rx.recv().unwrap();
    }
    frontend.shutdown();
}

#[test]
fn frontend_sustains_offered_load() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let (frontend, service) = start_recsys(&dir, 2, 2_000.0);
    let mut rng = Pcg32::seeded(300);
    let n = 200u64;
    let reqs: Vec<_> = (0..n).map(|i| service.synth_request(i, &mut rng, 200.0)).collect();
    let t0 = Instant::now();
    let receivers: Vec<_> = reqs
        .into_iter()
        .map(|mut r| {
            r.arrival = Instant::now();
            frontend.submit(r).unwrap()
        })
        .collect();
    for rx in receivers {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert!(resp.is_ok());
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let snap = frontend.metrics(RecSysService::MODEL_ID).unwrap().snapshot();
    assert_eq!(snap.served, n);
    // debug builds share cores with other (slow, unoptimized) test
    // binaries, which can starve the batcher thread — keep the strict
    // throughput/batching bounds for release runs only
    if cfg!(debug_assertions) {
        assert!(snap.mean_batch >= 1.0);
    } else {
        assert!(snap.mean_batch > 2.0, "mean batch {}", snap.mean_batch);
        // sanity: sustained > 50 req/s on CPU
        assert!(n as f64 / elapsed > 50.0, "qps {}", n as f64 / elapsed);
    }
    frontend.shutdown();
}

#[test]
fn mixed_model_traffic_served_with_separate_metrics() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    // register every family whose artifacts exist (CV artifacts only
    // appear in manifests rebuilt after the multi-model redesign)
    let recsys = RecSysService::from_manifest(&manifest).unwrap();
    let nmt = NmtService::from_manifest(&manifest).unwrap();
    let mut services: Vec<Arc<dyn ModelService>> =
        vec![Arc::new(recsys.clone()), Arc::new(nmt.clone())];
    let cv = if manifest.variants_for_prefix(CvService::PREFIX).is_empty() {
        None
    } else {
        let s = CvService::from_manifest(&manifest).unwrap();
        services.push(Arc::new(s.clone()));
        Some(s)
    };
    let n_models = services.len() as u64;

    let frontend = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.clone(),
            executors: 2,
            max_wait_us: 1_000.0,
            ..Default::default()
        },
        services,
    )
    .unwrap();
    assert!(frontend.models().contains(&"recsys".to_string()));
    assert!(frontend.models().contains(&"nmt".to_string()));

    // interleaved mixed traffic: round-robin across families
    let mut rng = Pcg32::seeded(400);
    let per_model = 20u64;
    let mut reqs = Vec::new();
    for i in 0..per_model {
        reqs.push(recsys.synth_request(3 * i, &mut rng, 200.0));
        reqs.push(nmt.synth_request(3 * i + 1, &mut rng, 200.0));
        if let Some(cv) = &cv {
            reqs.push(cv.synth_request(3 * i + 2, &mut rng, 0.0));
        }
    }
    let receivers: Vec<_> = reqs
        .into_iter()
        .map(|mut r| {
            r.arrival = Instant::now();
            frontend.submit(r).unwrap()
        })
        .collect();
    for rx in receivers {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        let outputs = resp.outcome.as_ref().expect("mixed-traffic response ok");
        match resp.model.as_str() {
            "recsys" => {
                let prob = resp.scalar_f32().unwrap();
                assert!(prob > 0.0 && prob < 1.0, "prob {prob}");
            }
            "nmt" => {
                // decode step returns [vocab] logits and [hidden] state
                assert_eq!(outputs.len(), 2);
                assert_eq!(outputs[0].elem_count(), nmt.vocab);
                assert_eq!(outputs[1].elem_count(), nmt.hidden);
            }
            "cv" => {
                let s = cv.as_ref().unwrap();
                assert_eq!(outputs[0].elem_count(), s.classes);
            }
            other => panic!("unexpected model {other}"),
        }
    }

    // per-model metrics are tracked separately and account for exactly
    // that family's traffic
    let mut total = 0u64;
    for (model, snap) in frontend.snapshot_all() {
        assert_eq!(snap.served, per_model, "{model} served {}", snap.served);
        assert_eq!(snap.failed, 0, "{model} had failures");
        assert!(snap.batches > 0, "{model} formed no batches");
        assert!(snap.mean_batch >= 1.0);
        total += snap.served;
    }
    assert_eq!(total, per_model * n_models);
    frontend.shutdown();
}

#[test]
fn unknown_model_and_bad_inputs_rejected_at_submit() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let (frontend, service) = start_recsys(&dir, 1, 500.0);
    let mut rng = Pcg32::seeded(500);

    // unknown routing key -> synchronous error
    let mut req = service.synth_request(1, &mut rng, 100.0);
    req.model = "no_such_model".to_string();
    let err = frontend.submit(req).unwrap_err();
    assert!(err.to_string().contains("no_such_model"), "{err:#}");

    // malformed inputs -> synchronous error (never reaches a batch)
    let mut bad = service.synth_request(2, &mut rng, 100.0);
    bad.inputs.pop();
    assert!(frontend.submit(bad).is_err());

    // the lane still works afterwards
    let resp =
        frontend.submit(service.synth_request(3, &mut rng, 200.0)).unwrap().recv().unwrap();
    assert!(resp.is_ok());
    frontend.shutdown();
}
