//! End-to-end serving integration: the full tier (router -> dynamic
//! batcher -> PJRT executors) serving the Fig-2 recommendation model.
//!
//! Requires `make artifacts` (skips cleanly otherwise).

use std::path::{Path, PathBuf};
use std::time::Instant;

use dcinfer::coordinator::{InferRequest, InferenceTier, TierConfig};
use dcinfer::util::rng::Pcg32;

// The tier tests saturate the CPU (PJRT executors + batcher threads);
// run them serially so timing-sensitive batching behaviour is stable.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn make_request(tier: &InferenceTier, rng: &mut Pcg32, id: u64) -> InferRequest {
    let mut dense = vec![0f32; tier.dense_dim];
    rng.fill_normal(&mut dense, 0.0, 1.0);
    let indices: Vec<i32> = (0..tier.n_tables * tier.pool_size)
        .map(|_| rng.zipf(tier.rows_per_table as u32, 1.05) as i32)
        .collect();
    InferRequest { id, dense, indices, arrival: Instant::now(), deadline_ms: 200.0 }
}

#[test]
fn tier_serves_batched_requests() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let tier = InferenceTier::start(TierConfig {
        artifacts_dir: dir,
        executors: 2,
        max_wait_us: 1_000.0,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Pcg32::seeded(100);

    // burst of 40 requests -> should form multi-request batches.
    // Pre-generate so the submit loop is pure channel sends (request
    // synthesis is slow in debug builds and would serialize the burst).
    let reqs: Vec<_> = (0..40).map(|i| make_request(&tier, &mut rng, i)).collect();
    let receivers: Vec<_> = reqs
        .into_iter()
        .map(|mut r| {
            r.arrival = Instant::now(); // stamp at submit, not generation
            tier.submit(r).unwrap()
        })
        .collect();

    let mut max_batch = 0usize;
    for rx in receivers {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(resp.prob > 0.0 && resp.prob < 1.0, "prob {}", resp.prob);
        max_batch = max_batch.max(resp.batch_size);
    }
    let snap = tier.metrics.snapshot();
    assert_eq!(snap.served, 40);
    if !cfg!(debug_assertions) {
        assert!(max_batch > 1, "burst never batched (max batch {max_batch})");
        assert!(snap.batches < 40, "{} batches for 40 requests", snap.batches);
    }
    tier.shutdown();
}

#[test]
fn tier_responses_match_single_request_path() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        return;
    };
    // serve the same request twice: once alone, once inside a burst —
    // the prediction must be identical (batching is semantically
    // transparent).
    let tier = InferenceTier::start(TierConfig {
        artifacts_dir: dir,
        executors: 1,
        max_wait_us: 500.0,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Pcg32::seeded(200);
    let probe = make_request(&tier, &mut rng, 999);

    let solo = tier.submit(probe.clone()).unwrap().recv().unwrap();

    let extra: Vec<_> = (0..15).map(|i| make_request(&tier, &mut rng, i)).collect();
    let mut probe2 = probe.clone();
    probe2.arrival = Instant::now();
    let mut receivers = vec![tier.submit(probe2).unwrap()];
    for mut r in extra {
        r.arrival = Instant::now();
        receivers.push(tier.submit(r).unwrap());
    }
    let burst = receivers.remove(0).recv().unwrap();
    assert!(
        (solo.prob - burst.prob).abs() < 1e-5,
        "solo {} vs batched {}",
        solo.prob,
        burst.prob
    );
    for rx in receivers {
        rx.recv().unwrap();
    }
    tier.shutdown();
}

#[test]
fn tier_sustains_offered_load() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let tier = InferenceTier::start(TierConfig {
        artifacts_dir: dir,
        executors: 2,
        max_wait_us: 2_000.0,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Pcg32::seeded(300);
    let n = 200u64;
    let reqs: Vec<_> = (0..n).map(|i| make_request(&tier, &mut rng, i)).collect();
    let t0 = Instant::now();
    let receivers: Vec<_> = reqs
        .into_iter()
        .map(|mut r| {
            r.arrival = Instant::now();
            tier.submit(r).unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let snap = tier.metrics.snapshot();
    assert_eq!(snap.served, n);
    // debug builds share cores with other (slow, unoptimized) test
    // binaries, which can starve the batcher thread — keep the strict
    // throughput/batching bounds for release runs only
    if cfg!(debug_assertions) {
        assert!(snap.mean_batch >= 1.0);
    } else {
        assert!(snap.mean_batch > 2.0, "mean batch {}", snap.mean_batch);
        // sanity: sustained > 50 req/s on CPU
        assert!(n as f64 / elapsed > 50.0, "qps {}", n as f64 / elapsed);
    }
    tier.shutdown();
}
