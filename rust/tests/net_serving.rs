//! Loopback integration of the network serving plane: `ServingServer`
//! + `DcClient` over an ephemeral 127.0.0.1 port, driving the
//! self-synthesized fixture on the native backend (runs with and
//! without the `pjrt` feature, no `make artifacts` needed).
//!
//! Covers: mixed recsys/cv/nmt traffic with out-of-order completion,
//! admission-control sheds surfacing as `InferError::Overloaded` on
//! the client (deadline-infeasible and queue-overload), malformed
//! frames never panicking the server, and graceful shutdown losing no
//! in-flight responses.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dcinfer::coordinator::wire::{self, FrameKind};
use dcinfer::coordinator::{
    DcClient, FrontendConfig, InferError, ModelService, ServerConfig, ServingFrontend,
    ServingServer,
};
use dcinfer::models::{CvService, NmtService, RecSysService};
use dcinfer::runtime::{synthetic_artifacts_dir, BackendSpec, Manifest, Precision};
use dcinfer::util::rng::Pcg32;

// loopback serving saturates the machine with executor + connection
// threads; serialize so timing-sensitive behaviour stays stable
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

struct Rig {
    dir: PathBuf,
    frontend: Arc<ServingFrontend>,
    server: ServingServer,
    recsys: RecSysService,
    cv: CvService,
    nmt: NmtService,
}

impl Rig {
    fn start(tag: &str, executors: usize, max_queue_depth: usize) -> Rig {
        let dir = synthetic_artifacts_dir(tag).expect("fixture");
        let manifest = Manifest::load(&dir).expect("manifest");
        let recsys = RecSysService::from_manifest(&manifest).expect("recsys config");
        let cv = CvService::from_manifest(&manifest).expect("cv config");
        let nmt = NmtService::from_manifest(&manifest).expect("nmt config");
        let services: Vec<Arc<dyn ModelService>> =
            vec![Arc::new(recsys.clone()), Arc::new(cv.clone()), Arc::new(nmt.clone())];
        let frontend = Arc::new(
            ServingFrontend::start(
                FrontendConfig {
                    artifacts_dir: dir.clone(),
                    executors,
                    max_wait_us: 500.0,
                    backend: BackendSpec::native(Precision::Fp32),
                    max_queue_depth,
                    ..Default::default()
                },
                services,
            )
            .expect("frontend start"),
        );
        let server = ServingServer::bind(frontend.clone(), "127.0.0.1:0", ServerConfig::default())
            .expect("server bind");
        Rig { dir, frontend, server, recsys, cv, nmt }
    }

    fn finish(self) {
        self.server.shutdown();
        self.frontend.shutdown();
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[test]
fn mixed_traffic_round_trips_over_loopback() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rig = Rig::start("net_mixed", 2, 4096);
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");
    let mut rng = Pcg32::seeded(1000);

    let per_model = 20u64;
    let mut receivers = Vec::new();
    for i in 0..per_model {
        let r = rig.recsys.synth_request(3 * i, &mut rng, 500.0);
        receivers.push(("recsys", 3 * i, client.submit(&r).unwrap()));
        let r = rig.nmt.synth_request(3 * i + 1, &mut rng, 500.0);
        receivers.push(("nmt", 3 * i + 1, client.submit(&r).unwrap()));
        let r = rig.cv.synth_request(3 * i + 2, &mut rng, 0.0);
        receivers.push(("cv", 3 * i + 2, client.submit(&r).unwrap()));
    }
    for (model, id, rx) in receivers {
        let cr = rx.recv_timeout(Duration::from_secs(60)).expect("response arrives");
        let resp = &cr.resp;
        assert_eq!(resp.model, model);
        assert_eq!(resp.id, id, "user request ids survive the corr-id rewrite");
        let outputs = resp.outcome.as_ref().expect("served ok");
        match model {
            "recsys" => {
                let p = resp.scalar_f32().unwrap();
                assert!(p > 0.0 && p < 1.0, "prob {p}");
            }
            "nmt" => {
                assert_eq!(outputs.len(), 2);
                assert_eq!(outputs[0].elem_count(), rig.nmt.vocab);
                assert_eq!(outputs[1].elem_count(), rig.nmt.hidden);
            }
            "cv" => assert_eq!(outputs[0].elem_count(), rig.cv.classes),
            other => panic!("unexpected model {other}"),
        }
        assert!(cr.rtt_us > 0.0);
    }

    // per-model accounting happened server-side
    for (model, snap) in rig.frontend.snapshot_all() {
        assert_eq!(snap.served, per_model, "{model} served {}", snap.served);
        assert_eq!(snap.failed, 0, "{model} failures");
        assert_eq!(snap.shed, 0, "{model} sheds");
        assert_eq!(snap.queue_depth, 0, "{model} depth drained");
    }
    assert_eq!(client.in_flight(), 0);
    client.close();
    rig.finish();
}

#[test]
fn infeasible_deadline_is_shed_as_overloaded() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rig = Rig::start("net_deadline", 1, 4096);
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");
    let mut rng = Pcg32::seeded(2000);

    // 1 ms deadline against the default 10 ms execution reserve:
    // deterministically infeasible, answered immediately
    let req = rig.recsys.synth_request(1, &mut rng, 1.0);
    let cr = client.call(&req).expect("shed still answers");
    assert!(cr.shed(), "expected a shed, got {:?}", cr.resp.outcome);
    match &cr.resp.outcome {
        Err(InferError::Overloaded(msg)) => assert!(msg.contains("infeasible"), "{msg}"),
        other => panic!("expected Overloaded, got {other:?}"),
    }
    let snap = rig.frontend.metrics(RecSysService::MODEL_ID).unwrap().snapshot();
    assert_eq!(snap.shed, 1);
    assert_eq!(snap.served, 0);

    // the lane still serves feasible traffic afterwards
    let ok = client.call(&rig.recsys.synth_request(2, &mut rng, 500.0)).unwrap();
    assert!(ok.resp.is_ok(), "{:?}", ok.resp.outcome);
    client.close();
    rig.finish();
}

#[test]
fn queue_overload_sheds_instead_of_stalling() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // a depth bound of 2 with one executor: a back-to-back burst far
    // outpaces execution, so most of it must shed
    let rig = Rig::start("net_overload", 1, 2);
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");
    let mut rng = Pcg32::seeded(3000);

    let n = 100u64;
    let receivers: Vec<_> = (0..n)
        .map(|i| client.submit(&rig.recsys.synth_request(i, &mut rng, 500.0)).unwrap())
        .collect();
    let (mut ok, mut shed, mut other) = (0u64, 0u64, 0u64);
    for rx in receivers {
        let cr = rx.recv_timeout(Duration::from_secs(60)).expect("every request is answered");
        if cr.shed() {
            shed += 1;
        } else if cr.resp.is_ok() {
            ok += 1;
        } else {
            other += 1;
        }
    }
    assert_eq!(ok + shed + other, n);
    assert_eq!(other, 0, "only served-or-shed outcomes expected");
    assert!(ok >= 1, "nothing served under overload");
    assert!(shed > 0, "a 100-request burst against depth bound 2 must shed");
    let snap = rig.frontend.metrics(RecSysService::MODEL_ID).unwrap().snapshot();
    assert_eq!(snap.shed, shed);
    assert_eq!(snap.served, ok);
    client.close();
    rig.finish();
}

#[test]
fn malformed_frames_never_kill_the_server() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rig = Rig::start("net_garbage", 1, 4096);
    let addr = rig.server.local_addr();

    // raw garbage: the server closes that connection, nothing else
    {
        let mut raw = TcpStream::connect(addr).expect("raw connect");
        raw.write_all(&[0xFFu8; 64]).expect("write garbage");
        raw.flush().unwrap();
        let mut buf = [0u8; 16];
        // server closes: read eventually returns 0 (or a reset error)
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        match raw.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(k) => panic!("server answered {k} bytes to garbage"),
        }
    }

    // an intact frame with an undecodable payload: answered with
    // BadRequest on the same correlation id, connection stays up
    {
        let mut raw = TcpStream::connect(addr).expect("framed connect");
        wire::write_frame(&mut raw, FrameKind::Request, 77, b"this is not a request").unwrap();
        raw.flush().unwrap();
        let frame = wire::read_frame(&mut raw, wire::DEFAULT_MAX_FRAME)
            .expect("readable response")
            .expect("a response frame");
        assert_eq!(frame.kind, FrameKind::Response);
        assert_eq!(frame.corr, 77);
        let resp = wire::decode_response(&frame.payload).unwrap();
        assert!(
            matches!(resp.outcome, Err(InferError::BadRequest(_))),
            "{:?}",
            resp.outcome
        );
    }

    // the server is still fully alive for well-formed clients
    let client = DcClient::connect(addr).expect("connect after garbage");
    let mut rng = Pcg32::seeded(4000);
    let cr = client.call(&rig.recsys.synth_request(9, &mut rng, 500.0)).unwrap();
    assert!(cr.resp.is_ok(), "{:?}", cr.resp.outcome);
    client.close();
    rig.finish();
}

#[test]
fn graceful_shutdown_loses_no_inflight_responses() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rig = Rig::start("net_drain", 2, 4096);
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");
    let mut rng = Pcg32::seeded(5000);

    let n = 30u64;
    let receivers: Vec<_> = (0..n)
        .map(|i| client.submit(&rig.recsys.synth_request(i, &mut rng, 10_000.0)).unwrap())
        .collect();
    // let the server ingest the whole burst, then drain mid-flight
    std::thread::sleep(Duration::from_millis(300));
    rig.server.shutdown();

    // every in-flight request still gets its real response before the
    // connection winds down
    for rx in receivers {
        let cr = rx.recv_timeout(Duration::from_secs(60)).expect("no lost responses");
        assert!(cr.resp.is_ok(), "in-flight request lost: {:?}", cr.resp.outcome);
    }
    client.close();
    rig.finish();
}
