//! Differential fuzzing: compiled plans vs the interpreter oracle.
//!
//! Generates ~2k random valid op programs — random shapes including
//! degenerate dims (width 1, M=1, K=1), random unary/binary chains,
//! dead inputs, aliasing `flatten`, occasional conv prologues and
//! embedding lookups — builds each as a native artifact at one of the
//! four precisions, and asserts the compiled plan's outputs are
//! bit-identical to the interpreter's (fp32/fp16), falling back to the
//! precision's SQNR bound for the int8 paths. This is the seal on the
//! epilogue-folding numerics contract: fusion must not change what any
//! element sees.

use std::collections::HashMap;

use dcinfer::quant::sqnr_db;
use dcinfer::runtime::{
    build_native_artifact, ArtifactMeta, DType, HostTensor, NamedTensor, Precision, TensorMeta,
};
use dcinfer::util::json::Json;
use dcinfer::util::rng::Pcg32;

const CASES: usize = 2048;

/// One generated case: everything `build_native_artifact` needs.
struct Case {
    meta: ArtifactMeta,
    weights: Vec<NamedTensor>,
}

/// A dense `[m, width]` f32 value available to later ops.
#[derive(Clone)]
struct Val {
    name: String,
    width: usize,
}

struct Gen<'a> {
    rng: &'a mut Pcg32,
    m: usize,
    vals: Vec<Val>,
    ops: Vec<String>,
    weights: Vec<NamedTensor>,
    inputs: Vec<TensorMeta>,
    /// shape of every op-produced value (legal artifact outputs)
    produced: Vec<(String, Vec<usize>)>,
    next_id: usize,
}

impl Gen<'_> {
    fn fresh(&mut self, prefix: &str) -> String {
        self.next_id += 1;
        format!("{prefix}{}", self.next_id)
    }

    fn weight(&mut self, prefix: &str, shape: &[usize], std: f32) -> String {
        let name = self.fresh(prefix);
        let count: usize = shape.iter().product();
        let mut data = vec![0f32; count];
        self.rng.fill_normal(&mut data, 0.0, std);
        self.weights.push(NamedTensor {
            name: name.clone(),
            tensor: HostTensor::from_f32(shape, &data),
        });
        name
    }

    fn pick_val(&mut self) -> Val {
        self.vals[self.rng.below(self.vals.len() as u32) as usize].clone()
    }

    fn pick_width(&mut self) -> usize {
        [1usize, 2, 3, 4, 5, 8][self.rng.below(6) as usize]
    }

    fn act(&mut self) -> &'static str {
        ["none", "relu", "sigmoid", "tanh"][self.rng.below(4) as usize]
    }

    fn unary_fn(&mut self) -> &'static str {
        ["relu", "sigmoid", "tanh", "one_minus"][self.rng.below(4) as usize]
    }

    fn push_dense(&mut self, name: String, width: usize) {
        self.produced.push((name.clone(), vec![self.m, width]));
        self.vals.push(Val { name, width });
    }

    fn emit_fc(&mut self, input: &Val, n: usize) -> String {
        let out = self.fresh("v");
        let w = self.weight("w", &[n, input.width], 0.4);
        let bias = if self.rng.below(2) == 0 {
            let b = self.weight("b", &[n], 0.1);
            format!(r#", "b": "{b}""#)
        } else {
            String::new()
        };
        let act = self.act();
        self.ops.push(format!(
            r#"{{"op": "fc", "out": "{out}", "in": "{}", "w": "{w}"{bias}, "act": "{act}"}}"#,
            input.name
        ));
        self.push_dense(out.clone(), n);
        out
    }

    fn emit_unary(&mut self, input: &Val) -> String {
        let out = self.fresh("v");
        let f = self.unary_fn();
        self.ops.push(format!(
            r#"{{"op": "unary", "fn": "{f}", "out": "{out}", "in": "{}"}}"#,
            input.name
        ));
        self.push_dense(out.clone(), input.width);
        out
    }

    fn emit_binary(&mut self, a: &Val, b: &Val) -> String {
        assert_eq!(a.width, b.width);
        let out = self.fresh("v");
        let f = ["add", "mul"][self.rng.below(2) as usize];
        self.ops.push(format!(
            r#"{{"op": "binary", "fn": "{f}", "out": "{out}", "a": "{}", "b": "{}"}}"#,
            a.name, b.name
        ));
        self.push_dense(out.clone(), a.width);
        out
    }

    /// Pick a partner with the same width (may be the same value — the
    /// both-operands-are-the-chain-value refusal case).
    fn width_partner(&mut self, a: &Val) -> Val {
        let mates: Vec<Val> =
            self.vals.iter().filter(|v| v.width == a.width).cloned().collect();
        mates[self.rng.below(mates.len() as u32) as usize].clone()
    }
}

fn gen_case(rng: &mut Pcg32, idx: usize) -> Case {
    let m = [1usize, 2, 3, 5][rng.below(4) as usize];
    let mut g = Gen {
        rng,
        m,
        vals: Vec::new(),
        ops: Vec::new(),
        weights: Vec::new(),
        inputs: Vec::new(),
        produced: Vec::new(),
        next_id: 0,
    };

    // dense inputs (never artifact outputs)
    for j in 0..1 + g.rng.below(2) {
        let w = g.pick_width();
        let name = format!("in{j}");
        g.inputs.push(TensorMeta { name: name.clone(), dtype: DType::F32, shape: vec![m, w] });
        g.vals.push(Val { name, width: w });
    }
    // dead input: decoded into its slot, read by nothing
    if g.rng.below(5) == 0 {
        let w = g.pick_width();
        g.inputs.push(TensorMeta { name: "dead".into(), dtype: DType::F32, shape: vec![m, w] });
    }

    // conv prologue: conv [-> unary] -> flatten, rejoining the dense world
    if g.rng.below(4) == 0 {
        g.inputs.push(TensorMeta {
            name: "image".into(),
            dtype: DType::F32,
            shape: vec![m, 1, 4, 4],
        });
        let co = 1 + g.rng.below(3) as usize;
        let kh = 2 + g.rng.below(2) as usize;
        let stride = 1 + g.rng.below(2) as usize;
        let phi = g.rng.below(2) as usize;
        let ho = (4 + phi - kh) / stride + 1;
        let w = g.weight("cw", &[co, 1, kh, kh], 0.3);
        let act = g.act();
        let cout = g.fresh("c");
        g.ops.push(format!(
            r#"{{"op": "conv2d", "out": "{cout}", "in": "image", "w": "{w}", "act": "{act}", "stride": {stride}, "pad": [0, {phi}]}}"#
        ));
        g.produced.push((cout.clone(), vec![m, co, ho, ho]));
        let mut flat_src = cout;
        if g.rng.below(2) == 0 {
            let u = g.fresh("cu");
            let f = g.unary_fn();
            g.ops.push(format!(
                r#"{{"op": "unary", "fn": "{f}", "out": "{u}", "in": "{flat_src}"}}"#
            ));
            g.produced.push((u.clone(), vec![m, co, ho, ho]));
            flat_src = u;
        }
        let fout = g.fresh("cf");
        g.ops
            .push(format!(r#"{{"op": "flatten", "out": "{fout}", "in": "{flat_src}"}}"#));
        g.push_dense(fout, co * ho * ho);
    }

    // embedding lookup feeding the dense world
    if g.rng.below(5) == 0 {
        let rows = [5usize, 17][g.rng.below(2) as usize];
        let dim = [2usize, 4][g.rng.below(2) as usize];
        let pool = 3usize;
        g.inputs.push(TensorMeta { name: "idx".into(), dtype: DType::I32, shape: vec![m, pool] });
        let tbl = g.weight("tbl", &[rows, dim], 0.5);
        let out = g.fresh("e");
        g.ops.push(format!(
            r#"{{"op": "embed_pool", "out": "{out}", "indices": "idx", "table": "{tbl}"}}"#
        ));
        g.push_dense(out, dim);
    }

    // random dense op soup
    let n_ops = 1 + g.rng.below(5);
    for _ in 0..n_ops {
        let r = g.rng.below(100);
        if r < 35 {
            let x = g.pick_val();
            let n = g.pick_width();
            g.emit_fc(&x, n);
        } else if r < 55 {
            let x = g.pick_val();
            g.emit_unary(&x);
        } else if r < 70 {
            let a = g.pick_val();
            let b = g.width_partner(&a);
            g.emit_binary(&a, &b);
        } else if r < 80 {
            let x = g.pick_val();
            let out = g.fresh("fl");
            g.ops.push(format!(r#"{{"op": "flatten", "out": "{out}", "in": "{}"}}"#, x.name));
            g.push_dense(out, x.width);
        } else {
            // deliberate fusable chain: fc -> unary [-> binary]
            let x = g.pick_val();
            // pick n matching an existing width so a binary partner exists
            let n = g.pick_val().width;
            let fc = g.emit_fc(&x, n);
            let fc_val = Val { name: fc, width: n };
            let u = g.emit_unary(&fc_val);
            if g.rng.below(2) == 0 {
                let u_val = Val { name: u, width: n };
                let partner = g.width_partner(&u_val);
                g.emit_binary(&u_val, &partner);
            }
        }
    }

    // outputs: the last produced value, plus sometimes an earlier one
    // (which may be a chain intermediate — the refusal paths must also
    // stay bit-identical)
    let shape_of: HashMap<&str, &Vec<usize>> =
        g.produced.iter().map(|(n, s)| (n.as_str(), s)).collect();
    let last = g.produced.last().unwrap().0.clone();
    let mut out_names = vec![last];
    if g.rng.below(3) == 0 && g.produced.len() > 1 {
        let extra = g.produced[g.rng.below(g.produced.len() as u32) as usize].0.clone();
        if extra != out_names[0] {
            out_names.push(extra);
        }
    }
    let outputs: Vec<TensorMeta> = out_names
        .iter()
        .map(|n| TensorMeta {
            name: n.clone(),
            dtype: DType::F32,
            shape: shape_of[n.as_str()].clone(),
        })
        .collect();

    let mut prog = String::from("[");
    for (i, op) in g.ops.iter().enumerate() {
        if i > 0 {
            prog.push(',');
        }
        prog.push_str(op);
    }
    prog.push(']');

    let meta = ArtifactMeta {
        name: format!("fuzz_{idx}"),
        hlo: String::new(),
        model: None,
        weights: None,
        weight_params: vec![],
        inputs: g.inputs,
        outputs,
        batch: m,
        precision: Precision::Fp32,
        program: Json::parse(&prog).expect("generated program must parse"),
    };
    Case { meta, weights: g.weights }
}

fn bits(ts: &[HostTensor]) -> Vec<Vec<u32>> {
    ts.iter().map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect()).collect()
}

#[test]
fn compiled_plans_match_the_interpreter_on_random_programs() {
    let precisions =
        [Precision::Fp32, Precision::Fp16, Precision::I8Acc32, Precision::I8Acc16];
    let mut rng = Pcg32::seeded(0xD1FF);
    let mut fused_chains = 0usize;
    let mut fused_cases = 0usize;
    for i in 0..CASES {
        let p = precisions[i % precisions.len()];
        let case = gen_case(&mut rng, i);
        let art = build_native_artifact(case.meta, &case.weights, p, 1)
            .unwrap_or_else(|e| panic!("case {i}: build failed: {e:#}"));
        let rep = art.fusion_report();
        fused_chains += rep.chains.len();
        fused_cases += (!rep.chains.is_empty()) as usize;
        assert!(
            rep.plan_steps + 3 * rep.chains.len() >= rep.interp_ops,
            "case {i}: steps {} chains {} ops {}",
            rep.plan_steps,
            rep.chains.len(),
            rep.interp_ops
        );

        let inputs = art.synth_inputs(0xF00D + i as u64);
        let c1 = art.run_compiled(&inputs).unwrap_or_else(|e| panic!("case {i}: {e:#}"));
        let oracle = art.run_interpreted(&inputs).unwrap();
        // a second compiled run must not depend on stale arena state
        // (fused chains leave elided intermediate slots untouched)
        let c2 = art.run_compiled(&inputs).unwrap();
        assert_eq!(bits(&c1), bits(&c2), "case {i}: compiled runs disagree across arena reuse");

        for (o, (cv, iv)) in c1.iter().zip(oracle.iter()).enumerate() {
            let (cv, iv) = (cv.as_f32().unwrap(), iv.as_f32().unwrap());
            let identical =
                cv.iter().zip(iv.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
            if identical {
                continue;
            }
            // int8 paths may requantize differently batch-to-batch;
            // hold them to the precision's accuracy contract instead
            assert!(
                matches!(p, Precision::I8Acc32 | Precision::I8Acc16),
                "case {i} output {o}: {p} must be bit-identical"
            );
            let db = sqnr_db(&iv, &cv);
            assert!(
                db >= p.min_sqnr_db(),
                "case {i} output {o}: {p} sqnr {db:.1} dB below bound"
            );
        }
    }
    // the corpus must actually exercise folding, not just refusal paths
    assert!(
        fused_chains > 50,
        "only {fused_chains} fused chains across {CASES} cases ({fused_cases} cases)"
    );
}
