//! Property-based tests (hand-rolled sweeps — proptest is unavailable
//! offline): randomized inputs over many seeds asserting invariants of
//! the coordinator, GEMM kernels, quantizer and roofline allocator.

use dcinfer::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use dcinfer::coordinator::request::InferRequest;
use dcinfer::gemm::{
    detect_isa,
    fp16::gemm_f16_ctx,
    fp32::{gemm_f32_ctx, gemm_ref},
    i8acc16::{gemm_i8_acc16, gemm_i8_acc16_ctx},
    i8acc32::{gemm_i8_acc32, gemm_i8_acc32_ctx, gemm_i8_ref},
    split_outliers, GemmCtx, Isa, OutputPipeline, PackedBF16, PackedBF32, PackedBI8,
    PackedBI8Acc16,
};
use dcinfer::models::representative_zoo;
use dcinfer::perfmodel::{roofline_model_with_policy, AllocPolicy, DeviceSpec};
use dcinfer::quant::qparams::QParams;
use dcinfer::util::f16::{f16_to_f32, f32_to_f16};
use dcinfer::util::rng::Pcg32;

const CASES: u64 = 60;

// ---------------------------------------------------------------------------
// GEMM invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_i8acc32_exact_for_random_shapes() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(seed);
        let m = 1 + rng.below(12) as usize;
        let n = 1 + rng.below(70) as usize;
        let k = 1 + rng.below(200) as usize;
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let packed = PackedBI8::pack(&b, n, k);
        let pipe = OutputPipeline::per_tensor(n, 0, 1.0, packed.rowsum.clone(), false);
        let mut c = vec![0f32; m * n];
        gemm_i8_acc32(&a, m, &packed, &pipe, &mut c);
        let want = gemm_i8_ref(&a, m, &b, n, k);
        for (x, y) in c.iter().zip(&want) {
            assert_eq!(*x, *y as f32, "seed {seed} ({m},{n},{k})");
        }
    }
}

#[test]
fn prop_acc16_equals_acc32_for_any_weights() {
    // the outlier split must make the 16-bit path exact for the *full*
    // int8 weight range, for any shape
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(1000 + seed);
        let m = 1 + rng.below(8) as usize;
        let n = 1 + rng.below(48) as usize;
        let k = 1 + rng.below(160) as usize;
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let p16 = PackedBI8Acc16::pack(&b, n, k);
        let p32 = PackedBI8::pack(&b, n, k);
        let pipe = OutputPipeline::per_tensor(n, 3, 0.01, p32.rowsum.clone(), true);
        let mut c16 = vec![0f32; m * n];
        let mut c32 = vec![0f32; m * n];
        gemm_i8_acc16(&a, m, &p16, &pipe, &mut c16);
        gemm_i8_acc32(&a, m, &p32, &pipe, &mut c32);
        assert_eq!(c16, c32, "seed {seed} ({m},{n},{k})");
    }
}

#[test]
fn prop_outlier_split_reconstructs_for_all_bit_widths() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(2000 + seed);
        let n = 1 + rng.below(20) as usize;
        let k = 1 + rng.below(60) as usize;
        let bits = 2 + rng.below(7); // 2..=8
        let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let (main, out) = split_outliers(&b, n, k, bits);
        let hi = (1i32 << (bits - 1)) - 1;
        let lo = -(1i32 << (bits - 1));
        let mut recon = vec![0i32; n * k];
        for (i, &m) in main.iter().enumerate() {
            assert!((lo..=hi).contains(&(m as i32)), "main out of range");
            recon[i] = m as i32;
        }
        for j in 0..n {
            for e in out.row_ptr[j] as usize..out.row_ptr[j + 1] as usize {
                recon[j * k + out.col_idx[e] as usize] += out.values[e] as i32;
            }
        }
        for (r, &orig) in recon.iter().zip(&b) {
            assert_eq!(*r, orig as i32, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked/SIMD/threaded kernel parity (the dispatch-core seal)
// ---------------------------------------------------------------------------

/// Shapes deliberately off every tile boundary, with the degenerate
/// M=1 / N=1 / K=1 and exact-multiple cases forced periodically, plus
/// shapes big enough (>= the kernel's ~1e6-op parallel threshold) that
/// the threaded contexts genuinely fan out: case 0 takes the panel
/// (N) partition at M=1, case 4 the MR-aligned row partition.
fn odd_shape(rng: &mut Pcg32, seed: u64) -> (usize, usize, usize) {
    match seed % 6 {
        0 => (1, 1024, 1024), // M=1 tall-skinny, panel-partitioned when threaded
        1 => (1 + rng.below(12) as usize, 1 + rng.below(90) as usize, 1), // K=1
        2 => (1 + rng.below(12) as usize, 1, 1 + rng.below(160) as usize), // N=1
        3 => (8, 32, 64), // exact tile multiples
        4 => (
            // row-partitioned when threaded, off-tile in every dim
            37 + rng.below(20) as usize,
            190 + rng.below(30) as usize,
            150 + rng.below(30) as usize,
        ),
        _ => (
            1 + rng.below(20) as usize,
            1 + rng.below(90) as usize,
            1 + rng.below(160) as usize,
        ),
    }
}

/// Every (ISA, thread-count) execution context worth distinguishing on
/// this host.
fn parity_ctxs() -> Vec<GemmCtx> {
    vec![
        GemmCtx::scalar(),
        GemmCtx { isa: Isa::Scalar, threads: 2 },
        GemmCtx::auto(),
        GemmCtx { isa: detect_isa(), threads: 3 },
    ]
}

#[test]
fn prop_fp32_blocked_simd_threaded_bit_exact_vs_naive() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(7000 + seed);
        let (m, n, k) = odd_shape(&mut rng, seed);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let relu = seed % 2 == 0;
        let packed = PackedBF32::pack(&b, n, k);
        let pipe = OutputPipeline::identity(n, relu);
        // identical k-ascending per-element accumulation: bit-exact
        let want = gemm_ref(&a, m, &b, n, k, relu);
        for ctx in parity_ctxs() {
            let mut c = vec![0f32; m * n];
            gemm_f32_ctx(&ctx, &a, m, &packed, &pipe, &mut c);
            assert_eq!(c, want, "seed {seed} ({m},{n},{k}) ctx {ctx:?}");
        }
    }
}

#[test]
fn prop_fp16_blocked_simd_threaded_bit_exact_vs_widened_naive() {
    use dcinfer::util::f16::{f16_to_f32, f32_to_f16};
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(8000 + seed);
        let (m, n, k) = odd_shape(&mut rng, seed);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let packed = PackedBF16::pack(&b, n, k);
        let pipe = OutputPipeline::identity(n, false);
        // reference: the pack-time f16 storage rule (round + flush
        // subnormals) applied to B, then the naive fp32 GEMM
        let b_wide: Vec<f32> = b
            .iter()
            .map(|&w| {
                let mut h = f32_to_f16(w);
                if h & 0x7c00 == 0 {
                    h &= 0x8000;
                }
                f16_to_f32(h)
            })
            .collect();
        let want = gemm_ref(&a, m, &b_wide, n, k, false);
        for ctx in parity_ctxs() {
            let mut c = vec![0f32; m * n];
            gemm_f16_ctx(&ctx, &a, m, &packed, &pipe, &mut c);
            assert_eq!(c, want, "seed {seed} ({m},{n},{k}) ctx {ctx:?}");
        }
    }
}

#[test]
fn prop_i8acc32_blocked_simd_threaded_exact_vs_naive() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(9000 + seed);
        let (m, n, k) = odd_shape(&mut rng, seed);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let packed = PackedBI8::pack(&b, n, k);
        // non-trivial zero point + scale: every ctx must still agree
        // exactly, because the pipeline math is identical per element
        let pipe = OutputPipeline::per_tensor(n, 7, 0.02, packed.rowsum.clone(), seed % 2 == 1);
        let exact_pipe = OutputPipeline::per_tensor(n, 0, 1.0, packed.rowsum.clone(), false);
        let want = gemm_i8_ref(&a, m, &b, n, k);
        let mut c_first: Option<Vec<f32>> = None;
        for ctx in parity_ctxs() {
            let mut c = vec![0f32; m * n];
            gemm_i8_acc32_ctx(&ctx, &a, m, &packed, &exact_pipe, &mut c);
            for (x, y) in c.iter().zip(&want) {
                assert_eq!(*x, *y as f32, "seed {seed} ({m},{n},{k}) ctx {ctx:?}");
            }
            gemm_i8_acc32_ctx(&ctx, &a, m, &packed, &pipe, &mut c);
            match &c_first {
                None => c_first = Some(c),
                Some(first) => assert_eq!(&c, first, "seed {seed} ctx {ctx:?}"),
            }
        }
    }
}

#[test]
fn prop_i8acc16_blocked_simd_threaded_exact_with_outliers() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(10_000 + seed);
        let (m, n, k) = odd_shape(&mut rng, seed);
        // outlier-populated weights: full int8 range on the small
        // shapes (adversarial ~50% density), trained-like Gaussians on
        // the parallel-sized ones (~10% — keeps the naive CSR
        // reference affordable); exactness must hold for both
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = if m * n * k >= 1_000_000 {
            (0..n * k)
                .map(|_| rng.normal_f32(0.0, 40.0).round().clamp(-127.0, 127.0) as i8)
                .collect()
        } else {
            (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
        };
        let packed = PackedBI8Acc16::pack(&b, n, k);
        let pipe = OutputPipeline::per_tensor(n, 0, 1.0, packed.rowsum.clone(), false);
        let want = gemm_i8_ref(&a, m, &b, n, k);
        for ctx in parity_ctxs() {
            let mut c = vec![0f32; m * n];
            gemm_i8_acc16_ctx(&ctx, &a, m, &packed, &pipe, &mut c);
            for (x, y) in c.iter().zip(&want) {
                assert_eq!(*x, *y as f32, "seed {seed} ({m},{n},{k}) ctx {ctx:?}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantizer invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_qparams_roundtrip_bounded_and_zero_exact() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(3000 + seed);
        let lo = rng.uniform_range(-100.0, 0.0);
        let hi = rng.uniform_range(0.01, 100.0);
        let bits = 2 + rng.below(7);
        let qp = QParams::from_range(lo, hi, bits, rng.below(2) == 0);
        // zero exactly representable
        assert_eq!(qp.fake_quant(0.0), 0.0, "seed {seed}");
        // in-range roundtrip bounded by scale/2 (+ asymmetric-zp slack)
        for _ in 0..20 {
            let x = rng.uniform_range(lo, hi);
            let err = (qp.fake_quant(x) - x).abs();
            assert!(err <= qp.scale * 1.01, "seed {seed}: x={x} err={err} scale={}", qp.scale);
        }
        // monotone: q(x) non-decreasing
        let (a, b) = (rng.uniform_range(lo, hi), rng.uniform_range(lo, hi));
        let (a, b) = if a <= b { (a, b) } else { (b, a) };
        assert!(qp.quantize(a) <= qp.quantize(b), "seed {seed}");
    }
}

#[test]
fn prop_f16_roundtrip_monotone_and_bounded() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(4000 + seed);
        let x = rng.uniform_range(-60000.0, 60000.0);
        let r = f16_to_f32(f32_to_f16(x));
        if x.abs() > 1e-3 {
            assert!(((r - x) / x).abs() <= 1.0 / 1024.0, "seed {seed}: {x} -> {r}");
        }
        // monotonicity on a random pair
        let y = rng.uniform_range(-60000.0, 60000.0);
        let (a, b) = if x <= y { (x, y) } else { (y, x) };
        assert!(
            f16_to_f32(f32_to_f16(a)) <= f16_to_f32(f32_to_f16(b)),
            "seed {seed}: monotonicity {a} {b}"
        );
    }
}

// ---------------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_preserves_fifo_and_loses_nothing() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(5000 + seed);
        let variants = match rng.below(3) {
            0 => vec![1, 4, 16],
            1 => vec![1, 2, 8, 32],
            _ => vec![1, 4, 16, 64],
        };
        let policy =
            BatchPolicy { variants, max_wait_us: 1e9, exec_reserve_us: 0.0 };
        let mut b = DynamicBatcher::new(policy);
        let n = 1 + rng.below(200) as u64;
        for id in 0..n {
            b.push(InferRequest::new("m", id, vec![], 1e9));
        }
        let mut seen = Vec::new();
        while let Some(f) = b.form() {
            assert!(f.variant >= f.requests.len(), "seed {seed}: variant too small");
            assert!(
                f.requests.len() <= f.variant,
                "seed {seed}: overfull batch"
            );
            seen.extend(f.requests.iter().map(|r| r.id));
        }
        // every request exactly once, in order
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// Roofline allocator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_allocator_never_exceeds_capacity_any_policy() {
    let zoo = representative_zoo();
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(6000 + seed);
        let cap_mb = rng.uniform_range(0.0, 200.0) as f64;
        let bw = [1.0, 10.0][rng.below(2) as usize];
        let dev = DeviceSpec::fig3(cap_mb, bw);
        let e = &zoo[rng.below(zoo.len() as u32) as usize];
        for policy in
            [AllocPolicy::GreedyValue, AllocPolicy::WeightsFirst, AllocPolicy::ActivationsFirst]
        {
            let r = roofline_model_with_policy(&e.desc, &dev, policy);
            let used: f64 = e
                .desc
                .layers
                .iter()
                .zip(&r.placements)
                .map(|(l, p)| {
                    let mut bytes = 0.0;
                    if p.weights_onchip {
                        bytes += l.weight_elems as f64 * dev.weight_bytes_per_elem;
                    }
                    if p.acts_onchip {
                        bytes += (l.act_in_elems + l.act_out_elems) as f64
                            * dev.act_bytes_per_elem;
                    }
                    bytes
                })
                .sum();
            assert!(
                used <= dev.onchip_capacity + 1.0,
                "seed {seed} {policy:?}: used {used} > cap {}",
                dev.onchip_capacity
            );
            assert!(r.achieved_ops <= dev.peak_ops * 1.0001, "seed {seed}: above peak");
            assert!(r.total_time_s >= 0.0 && r.achieved_ops.is_finite());
        }
    }
}
