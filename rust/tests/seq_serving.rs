//! Loopback integration of the sequence-serving plane: `SeqEngine`
//! behind a `ServingServer`, driven by `DcClient::submit_seq` over an
//! ephemeral 127.0.0.1 port on the self-synthesized fixture.
//!
//! The load-bearing seal is bit-exactness: a sequence decoded inside
//! the engine's continuously re-formed batches — neighbors joining
//! mid-flight, exiting on EOS, padding rows coming and going — must
//! stream exactly the token-by-token output of the single-sequence
//! reference decode. Also covered: typed refusal when the server has
//! no sequence plane, session-table sheds surfacing as `Overloaded`
//! on the client, and graceful shutdown losing no terminal frames.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dcinfer::coordinator::{
    reference_decode, DcClient, FrontendConfig, InferError, ModelService, SeqClientEvent,
    SeqConfig, SeqEngine, SeqFinish, ServerConfig, ServingFrontend, ServingServer,
};
use dcinfer::models::NmtService;
use dcinfer::runtime::{
    synthetic_artifacts_dir, BackendSpec, ExecBackend, Manifest, NativeBackend, Precision,
};

// loopback serving saturates the machine with executor + connection
// threads; serialize so timing-sensitive behaviour stays stable
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

struct Rig {
    dir: PathBuf,
    frontend: Arc<ServingFrontend>,
    engine: Arc<SeqEngine>,
    server: ServingServer,
    nmt: NmtService,
}

impl Rig {
    /// Fixture + one-lane frontend + sequence engine + server, all on
    /// the native fp32 backend.
    fn start(tag: &str, seq_cfg: SeqConfig) -> Rig {
        let dir = synthetic_artifacts_dir(tag).expect("fixture");
        let manifest = Manifest::load(&dir).expect("manifest");
        let nmt = NmtService::from_manifest(&manifest).expect("nmt config");
        let services: Vec<Arc<dyn ModelService>> = vec![Arc::new(nmt.clone())];
        let frontend = Arc::new(
            ServingFrontend::start(
                FrontendConfig {
                    artifacts_dir: dir.clone(),
                    executors: 1,
                    max_wait_us: 500.0,
                    backend: BackendSpec::native(Precision::Fp32),
                    ..Default::default()
                },
                services,
            )
            .expect("frontend start"),
        );
        let engine = Arc::new(
            SeqEngine::start(
                SeqConfig {
                    artifacts_dir: dir.clone(),
                    backend: BackendSpec::native(Precision::Fp32),
                    ..seq_cfg
                },
                nmt.clone(),
            )
            .expect("engine start"),
        );
        let server = ServingServer::bind_with_seq(
            frontend.clone(),
            Some(engine.clone()),
            "127.0.0.1:0",
            ServerConfig::default(),
        )
        .expect("server bind");
        Rig { dir, frontend, engine, server, nmt }
    }

    fn finish(self) {
        self.server.shutdown();
        self.engine.shutdown();
        self.frontend.shutdown();
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Drain one stream, checking step numbering as it goes.
fn drain(stream: dcinfer::coordinator::SeqStream) -> (Vec<u32>, dcinfer::coordinator::SeqDone) {
    let mut tokens = Vec::new();
    loop {
        match stream.recv() {
            Some(SeqClientEvent::Token { step, token, rtt_us }) => {
                assert_eq!(step as usize, tokens.len() + 1, "steps count from 1, in order");
                assert!(rtt_us > 0.0);
                tokens.push(token);
            }
            Some(SeqClientEvent::Done { done, .. }) => return (tokens, done),
            None => panic!("stream closed without a terminal SeqDone"),
        }
    }
}

/// The tentpole seal: sequences of very different lengths, submitted
/// in two waves so the second wave joins batches already mid-flight,
/// each stream token-for-token identical to the single-sequence
/// reference decode of the same initial state.
#[test]
fn streamed_tokens_match_the_single_sequence_reference() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rig = Rig::start("seqint_exact", SeqConfig::default());
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");
    let seed = 0xbeef;

    // mixed max_lens: some exit almost immediately (their slot frees
    // and the batch re-forms), some run long
    let max_lens: [u32; 8] = [40, 2, 30, 1, 25, 3, 35, 4];
    let mut streams = Vec::new();
    for (i, &ml) in max_lens.iter().enumerate().take(4) {
        let req = rig.nmt.synth_seq_request(i as u64, seed, ml, 0.0);
        streams.push((i as u64, ml, client.submit_seq(&req).expect("submit")));
    }
    // second wave lands while the first is decoding: the mid-flight join
    std::thread::sleep(Duration::from_millis(3));
    for (i, &ml) in max_lens.iter().enumerate().skip(4) {
        let req = rig.nmt.synth_seq_request(i as u64, seed, ml, 0.0);
        streams.push((i as u64, ml, client.submit_seq(&req).expect("submit")));
    }

    // the oracle: the same decode semantics at batch 1, no neighbors
    let manifest = Manifest::load(&rig.dir).expect("manifest");
    let artifact = NativeBackend::new(Precision::Fp32)
        .load(&manifest, "gru_step_b1")
        .expect("b1 artifact");
    let spec = rig.nmt.decode_spec();

    for (id, max_len, stream) in streams {
        let (tokens, done) = drain(stream);
        let (x0, h0) = rig.nmt.synth_seq_state(id, seed);
        let (want_tokens, want_finish) =
            reference_decode(artifact.as_ref(), &spec, &x0, &h0, max_len).expect("reference");
        assert_eq!(tokens, want_tokens, "sequence {id}: batched decode diverged");
        assert_eq!(done.outcome, Ok(want_finish), "sequence {id}");
        assert_eq!(done.steps as usize, tokens.len(), "sequence {id}");
    }

    let snap = rig.engine.snapshot();
    assert_eq!(snap.submitted, max_lens.len() as u64);
    assert_eq!(snap.done_eos + snap.done_maxlen, max_lens.len() as u64);
    assert_eq!(snap.live, 0, "every slot freed");
    assert!(snap.mean_fill() > 0.0);
    assert_eq!(client.seq_in_flight(), 0);
    client.close();
    rig.finish();
}

/// A server bound without a sequence plane answers `SeqSubmit` with a
/// typed `BadRequest` terminal frame — same connection, no tokens.
#[test]
fn server_without_sequence_plane_refuses_typed() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = synthetic_artifacts_dir("seqint_noplane").expect("fixture");
    let manifest = Manifest::load(&dir).expect("manifest");
    let nmt = NmtService::from_manifest(&manifest).expect("nmt config");
    let services: Vec<Arc<dyn ModelService>> = vec![Arc::new(nmt.clone())];
    let frontend = Arc::new(
        ServingFrontend::start(
            FrontendConfig {
                artifacts_dir: dir.clone(),
                executors: 1,
                backend: BackendSpec::native(Precision::Fp32),
                ..Default::default()
            },
            services,
        )
        .expect("frontend start"),
    );
    let server = ServingServer::bind(frontend.clone(), "127.0.0.1:0", ServerConfig::default())
        .expect("server bind");
    let client = DcClient::connect(server.local_addr()).expect("connect");

    let stream = client.submit_seq(&nmt.synth_seq_request(1, 1, 4, 0.0)).expect("submit");
    let (tokens, done) = stream.collect();
    assert!(tokens.is_empty(), "no tokens from a refused submit");
    assert_eq!(done.steps, 0);
    match done.outcome {
        Err(InferError::BadRequest(msg)) => {
            assert!(msg.contains("sequence plane"), "{msg}")
        }
        other => panic!("expected BadRequest, got {other:?}"),
    }

    // the regular request plane on the same connection is unharmed
    let mut rng = dcinfer::util::rng::Pcg32::seeded(70);
    let cr = client.call(&nmt.synth_request(2, &mut rng, 500.0)).expect("call");
    assert!(cr.resp.is_ok(), "{:?}", cr.resp.outcome);
    client.close();
    server.shutdown();
    frontend.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// With a session table of 1 and an EOS the decoder can never emit
/// (every sequence runs to max-len), a burst behind one long sequence
/// sheds as `Overloaded` — streamed, not dropped.
#[test]
fn session_table_bound_sheds_overloaded() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rig = Rig::start(
        "seqint_bound",
        SeqConfig {
            max_sessions: 1,
            max_len_cap: 100_000,
            // vocab is 16, so token 16 never appears: no early EOS exit
            eos_override: Some(16),
            ..SeqConfig::default()
        },
    );
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");

    // the occupant: long enough to still be decoding through the burst
    let occupant = client
        .submit_seq(&rig.nmt.synth_seq_request(0, 5, 10_000, 0.0))
        .expect("submit occupant");
    let burst: Vec<_> = (1..=4u64)
        .map(|id| {
            client.submit_seq(&rig.nmt.synth_seq_request(id, 5, 4, 0.0)).expect("submit burst")
        })
        .collect();

    let mut shed = 0;
    let mut served = 0;
    for stream in burst {
        let (_, done) = stream.collect();
        match done.outcome {
            Err(InferError::Overloaded(msg)) => {
                assert!(msg.contains("session table"), "{msg}");
                assert_eq!(done.steps, 0);
                shed += 1;
            }
            Ok(_) => served += 1,
            other => panic!("expected Overloaded or served, got {other:?}"),
        }
    }
    assert_eq!(shed + served, 4);
    assert!(shed >= 1, "a burst against a 1-session table must shed");
    let (tokens, done) = occupant.collect();
    assert_eq!(done.outcome, Ok(SeqFinish::MaxLen), "the occupant runs to its max-len");
    assert_eq!(tokens.len(), 10_000);
    assert_eq!(rig.engine.snapshot().shed, shed);
    client.close();
    rig.finish();
}

/// Seed shared by both executions of the compiled-vs-interpreted
/// scenario, so the two rigs decode exactly the same sequences.
const PLAN_SEED: u64 = 0x91a7;

/// Decode a fixed scenario — two waves so the second joins batches
/// mid-flight, a max_len=1 session, and an EOS forced on sequence 0's
/// very first step — and return every stream's tokens and outcome.
/// `interpret` flips the whole rig onto the interpreter oracle via
/// `DCINFER_EXEC=interpret` (read at artifact load).
fn decode_scenario(
    tag: &str,
    eos: u32,
    interpret: bool,
) -> Vec<(Vec<u32>, Result<SeqFinish, InferError>)> {
    if interpret {
        std::env::set_var("DCINFER_EXEC", "interpret");
    }
    let rig = Rig::start(tag, SeqConfig { eos_override: Some(eos), ..SeqConfig::default() });
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");
    let seed = PLAN_SEED;

    let max_lens: [u32; 5] = [20, 1, 8, 15, 2];
    let mut streams = Vec::new();
    for (i, &ml) in max_lens.iter().enumerate().take(3) {
        let req = rig.nmt.synth_seq_request(i as u64, seed, ml, 0.0);
        streams.push(client.submit_seq(&req).expect("submit"));
    }
    // second wave joins mid-flight
    std::thread::sleep(Duration::from_millis(3));
    for (i, &ml) in max_lens.iter().enumerate().skip(3) {
        let req = rig.nmt.synth_seq_request(i as u64, seed, ml, 0.0);
        streams.push(client.submit_seq(&req).expect("submit"));
    }

    let mut results = Vec::new();
    for stream in streams {
        let (tokens, done) = drain(stream);
        results.push((tokens, done.outcome));
    }
    client.close();
    rig.finish();
    if interpret {
        std::env::remove_var("DCINFER_EXEC");
    }
    results
}

/// The compiled plan is the default execution mode of the whole
/// serving stack; flipping the rig onto the interpreter oracle must
/// not change one token anywhere — mid-flight joins, a max_len=1
/// session, and an EOS hit on a sequence's first decode step included.
#[test]
fn compiled_and_interpreted_rigs_stream_identical_tokens() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // the fixture's gru family must actually fuse (fc -> add -> tanh),
    // otherwise this test compares the interpreter with itself
    let dir = synthetic_artifacts_dir("seqint_planpick").expect("fixture");
    let manifest = Manifest::load(&dir).expect("manifest");
    let backend = NativeBackend::new(Precision::Fp32);
    let artifact = backend.load_native(&manifest, "gru_step_b1").expect("b1 artifact");
    let rep = artifact.fusion_report();
    assert!(
        !rep.chains.is_empty(),
        "gru fixture mined no fused chains: {}",
        rep.summary()
    );
    // pick the EOS so sequence 0 terminates on its very first step
    let nmt = NmtService::from_manifest(&manifest).expect("nmt config");
    let spec = nmt.decode_spec();
    let (x0, h0) = nmt.synth_seq_state(0, PLAN_SEED);
    let (first_tokens, _) =
        reference_decode(&artifact, &spec, &x0, &h0, 1).expect("reference");
    let eos = first_tokens[0];
    let _ = std::fs::remove_dir_all(&dir);

    let compiled = decode_scenario("seqint_planc", eos, false);
    let interpreted = decode_scenario("seqint_plani", eos, true);
    assert_eq!(compiled, interpreted, "execution mode changed a streamed token");
    assert_eq!(
        compiled[0].1,
        Ok(SeqFinish::Eos),
        "sequence 0 was built to hit EOS on step one"
    );
    assert!(compiled[1].0.len() <= 1, "max_len=1 session must stop after one step");
}

/// Server shutdown mid-decode drains: every accepted sequence still
/// streams its tokens and terminal frame before the connection closes.
#[test]
fn graceful_shutdown_streams_every_done() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rig = Rig::start(
        "seqint_drain",
        SeqConfig {
            // run to max-len so sequences are genuinely mid-flight when
            // the drain starts
            eos_override: Some(16),
            ..SeqConfig::default()
        },
    );
    let client = DcClient::connect(rig.server.local_addr()).expect("connect");

    let streams: Vec<_> = (0..6u64)
        .map(|id| {
            client.submit_seq(&rig.nmt.synth_seq_request(id, 9, 200, 0.0)).expect("submit")
        })
        .collect();
    rig.server.shutdown();
    for (id, stream) in streams.into_iter().enumerate() {
        let (tokens, done) = stream.collect();
        assert_eq!(done.outcome, Ok(SeqFinish::MaxLen), "sequence {id} lost to the drain");
        assert_eq!(tokens.len(), 200, "sequence {id}");
    }
    client.close();
    rig.finish();
}
