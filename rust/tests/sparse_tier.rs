//! Sparse-tier seals: the sharded + cached embedding path must be a
//! drop-in replacement for the monolithic table.
//!
//! The tier's numerics contract (embedding/shard.rs module docs) is
//! placement invariance — every accumulation runs in f64 and rounds to
//! f32 once, so results cannot depend on shard count, replication or
//! cache state. The fp32 property tests therefore demand *bit-exact*
//! agreement with the monolithic f64-accumulated reference
//! (`EmbeddingTable::sparse_lengths_sum_exact`) across random
//! configurations, including empty bags and bags that span every
//! shard; int8 is held to the `Precision::min_sqnr_db` tolerance model
//! against the fp32 reference. The serving-stack tests run the tier
//! under a `ServingFrontend` with a self-synthesized artifacts fixture
//! (no `make artifacts` needed, runs under `--no-default-features`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dcinfer::coordinator::{FrontendConfig, ServingFrontend};
use dcinfer::embedding::{EmbeddingShardService, EmbeddingTable, LookupBatch, SparseTierConfig};
use dcinfer::models::RecSysService;
use dcinfer::quant::error::sqnr_db;
use dcinfer::runtime::{
    write_weights_file, BackendSpec, ExecBackend, HostTensor, Manifest, NamedTensor,
    NativeBackend, Precision,
};
use dcinfer::util::rng::Pcg32;

const CASES: u64 = 30;

/// Random batch with empty bags and uniform-random (cross-shard) ids.
fn random_batch(rng: &mut Pcg32, rows: usize, bags: usize, max_pool: usize) -> LookupBatch {
    let mut indices = Vec::new();
    let mut lengths = Vec::with_capacity(bags);
    for _ in 0..bags {
        // ~1 in 4 bags is empty — the paper's variable pooling extreme
        let len = if rng.below(4) == 0 { 0 } else { 1 + rng.below(max_pool as u32) };
        lengths.push(len);
        for _ in 0..len {
            indices.push(rng.below(rows as u32));
        }
    }
    LookupBatch { indices, lengths }
}

// ---------------------------------------------------------------------------
// Property: fp32 sharded+cached == monolithic exact reference, bitwise
// ---------------------------------------------------------------------------

#[test]
fn prop_fp32_sharded_cached_matches_monolithic_bit_exactly() {
    // shard counts >= 3 per the acceptance bar, plus 1 (degenerate) and
    // replicated layouts; cache both disabled and enabled
    let configs = [(1usize, 1usize, 0usize), (3, 1, 0), (3, 1, 64), (4, 2, 128), (6, 3, 32)];
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(900 + seed);
        let rows = 20 + rng.below(400) as usize;
        let dim = 1 + rng.below(48) as usize;
        let bags = 1 + rng.below(8) as usize;
        let table = EmbeddingTable::random(rows, dim, seed);
        let batch = random_batch(&mut rng, rows, bags, 12);
        let mut want = vec![0f32; bags * dim];
        table.sparse_lengths_sum_exact(&batch, &mut want);
        // the exact reference itself must track the f32 kernel closely
        let mut f32_kernel = vec![0f32; bags * dim];
        table.sparse_lengths_sum(&batch, &mut f32_kernel);
        for (a, b) in want.iter().zip(&f32_kernel) {
            assert!((a - b).abs() < 1e-3, "seed {seed}: exact {a} vs f32 {b}");
        }

        for (shards, replication, cache) in configs {
            let svc = EmbeddingShardService::start(SparseTierConfig {
                shards,
                replication,
                cache_capacity_rows: cache,
                admit_after: 1,
                ..Default::default()
            })
            .unwrap();
            let id = svc.register_table("prop/emb", &table, false).unwrap();
            // two passes: the second runs against a warm cache, and must
            // still be bit-identical to the cold pass and the reference
            for pass in 0..2 {
                let mut got = vec![0f32; bags * dim];
                svc.lookup(id, &batch, &mut got).unwrap();
                assert_eq!(
                    got, want,
                    "seed {seed} shards {shards} repl {replication} cache {cache} pass {pass}"
                );
            }
        }
    }
}

#[test]
fn prop_int8_sharded_within_quant_tolerance_and_placement_invariant() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(7000 + seed);
        let rows = 100 + rng.below(400) as usize;
        let dim = 8 + rng.below(32) as usize;
        let bags = 1 + rng.below(6) as usize;
        let table = EmbeddingTable::random(rows, dim, 50 + seed);
        let batch = random_batch(&mut rng, rows, bags, 16);
        let mut reference = vec![0f32; bags * dim];
        table.sparse_lengths_sum_exact(&batch, &mut reference);

        // int8 through one shard = the quantization-only baseline
        let mono = EmbeddingShardService::start(SparseTierConfig {
            shards: 1,
            replication: 1,
            cache_capacity_rows: 0,
            admit_after: 1,
            ..Default::default()
        })
        .unwrap();
        let id = mono.register_table("q/emb", &table, true).unwrap();
        let mut base = vec![0f32; bags * dim];
        mono.lookup(id, &batch, &mut base).unwrap();
        let db = sqnr_db(&reference, &base);
        assert!(
            db >= Precision::I8Acc32.min_sqnr_db(),
            "seed {seed}: int8 sqnr {db:.1} dB below bound"
        );

        // sharded + cached int8 must equal the one-shard int8 bitwise:
        // row-wise quantization is per-row, so placement cannot move it
        let svc = EmbeddingShardService::start(SparseTierConfig {
            shards: 4,
            replication: 2,
            cache_capacity_rows: 64,
            admit_after: 1,
            ..Default::default()
        })
        .unwrap();
        let id = svc.register_table("q/emb", &table, true).unwrap();
        for _ in 0..2 {
            let mut got = vec![0f32; bags * dim];
            svc.lookup(id, &batch, &mut got).unwrap();
            assert_eq!(got, base, "seed {seed}: int8 sharding changed the result");
        }
    }
}

#[test]
fn cross_shard_and_empty_bags_explicit() {
    // 10 rows over 3 ranges: [0,4) [4,8) [8,10); bag 1 touches all three
    let data: Vec<f32> = (0..10).flat_map(|r| vec![r as f32; 2]).collect();
    let table = EmbeddingTable::new(10, 2, data);
    let batch = LookupBatch { indices: vec![0, 5, 9, 1, 8], lengths: vec![0, 3, 0, 2] };
    let mut want = vec![0f32; 4 * 2];
    table.sparse_lengths_sum_exact(&batch, &mut want);
    assert_eq!(want, vec![0.0, 0.0, 14.0, 14.0, 0.0, 0.0, 9.0, 9.0]);

    let svc = EmbeddingShardService::start(SparseTierConfig {
        shards: 3,
        replication: 1,
        cache_capacity_rows: 4,
        admit_after: 1,
        ..Default::default()
    })
    .unwrap();
    let id = svc.register_table("x/emb", &table, false).unwrap();
    for _ in 0..3 {
        let mut got = vec![0f32; 4 * 2];
        svc.lookup(id, &batch, &mut got).unwrap();
        assert_eq!(got, want);
    }
    // an all-empty batch is legal and yields zeros
    let empty = LookupBatch { indices: vec![], lengths: vec![0, 0] };
    let mut got = vec![1f32; 2 * 2];
    svc.lookup(id, &empty, &mut got).unwrap();
    assert_eq!(got, vec![0.0; 4]);
}

#[test]
fn cache_counters_are_consistent_and_zipf_traffic_hits() {
    let rows = 10_000usize;
    let table = EmbeddingTable::random(rows, 16, 21);
    let svc = EmbeddingShardService::start(SparseTierConfig {
        shards: 4,
        replication: 1,
        cache_capacity_rows: 1024,
        admit_after: 2,
        ..Default::default()
    })
    .unwrap();
    let id = svc.register_table("zipf/emb", &table, false).unwrap();
    let mut rng = Pcg32::seeded(33);
    let mut out = vec![0f32; 8 * 16];
    let mut total_indices = 0u64;
    for _ in 0..80 {
        let batch = table.synth_batch(8, 32, 1.2, &mut rng);
        total_indices += batch.indices.len() as u64;
        svc.lookup(id, &batch, &mut out).unwrap();
    }
    let s = svc.snapshot();
    assert_eq!(s.tables.len(), 1);
    let t = &s.tables[0];
    assert_eq!(t.hits + t.misses, total_indices, "every index probes the cache exactly once");
    assert!(t.insertions <= t.misses, "insertions come from misses");
    assert!(t.evictions <= t.insertions, "evictions come from insertions");
    assert!(s.cached_rows <= 1024, "cache respects its bound");
    assert!(t.hit_rate() > 0.1, "zipf-1.2 head should hit: rate {}", t.hit_rate());
    assert!(s.indices == total_indices);
    assert!(s.ingress_bytes > 0 && s.egress_bytes > 0 && s.row_fetch_bytes > 0);
    // the cache must save boundary traffic vs an uncached tier
    let cold = EmbeddingShardService::start(SparseTierConfig {
        shards: 4,
        replication: 1,
        cache_capacity_rows: 0,
        admit_after: 2,
        ..Default::default()
    })
    .unwrap();
    let id2 = cold.register_table("zipf/emb", &table, false).unwrap();
    let mut rng = Pcg32::seeded(33);
    for _ in 0..80 {
        let batch = table.synth_batch(8, 32, 1.2, &mut rng);
        cold.lookup(id2, &batch, &mut out).unwrap();
    }
    assert!(
        svc.snapshot().ingress_bytes < cold.snapshot().ingress_bytes,
        "cache hits must shrink the index traffic to the shards"
    );
}

// ---------------------------------------------------------------------------
// Serving-stack fixture (native artifacts synthesized in a temp dir)
// ---------------------------------------------------------------------------

const RECSYS_PROG: &str = r#"[
  {"op": "fc", "out": "bot0", "in": "dense", "w": "bot_w0", "b": "bot_b0", "act": "relu"},
  {"op": "embed_pool", "out": "p0", "indices": "indices", "table": "emb_0", "slice": 0},
  {"op": "embed_pool", "out": "p1", "indices": "indices", "table": "emb_1", "slice": 1},
  {"op": "concat", "out": "z", "in": ["p0", "p1", "bot0"]},
  {"op": "fc", "out": "top0", "in": "z", "w": "top_w0", "b": "top_b0", "act": "none"},
  {"op": "unary", "fn": "sigmoid", "out": "prob", "in": "top0"}
]"#;

fn tensor(rng: &mut Pcg32, name: &str, shape: &[usize], std: f32) -> NamedTensor {
    let count: usize = shape.iter().product();
    let mut data = vec![0f32; count];
    rng.fill_normal(&mut data, 0.0, std);
    NamedTensor { name: name.to_string(), tensor: HostTensor::from_f32(shape, &data) }
}

/// The compiler-emitted shard metadata contract for the 64-row tables.
const GOOD_SHARDS: &str =
    r#"{"default_count": 2, "tables": {"emb_0": [[0, 32], [32, 64]], "emb_1": [[0, 32], [32, 64]]}}"#;
/// Drifted metadata: emb_1's ranges cover 60 of 64 rows.
const BAD_SHARDS: &str =
    r#"{"default_count": 2, "tables": {"emb_0": [[0, 32], [32, 64]], "emb_1": [[0, 32], [32, 60]]}}"#;

/// Recsys-lite fixture: dense 8, 2 tables of 64x8, pool 4, b1/b4.
fn fixture_dir(tag: &str) -> PathBuf {
    fixture_dir_with_shards(tag, GOOD_SHARDS)
}

fn fixture_dir_with_shards(tag: &str, shards_meta: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("dcinfer_sparse_{tag}_{}", std::process::id()));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Pcg32::seeded(4321);
    let weights = vec![
        tensor(&mut rng, "emb_0", &[64, 8], 0.5),
        tensor(&mut rng, "emb_1", &[64, 8], 0.5),
        tensor(&mut rng, "bot_w0", &[8, 8], 0.3),
        tensor(&mut rng, "bot_b0", &[8], 0.1),
        tensor(&mut rng, "top_w0", &[1, 24], 0.2),
        tensor(&mut rng, "top_b0", &[1], 0.1),
    ];
    write_weights_file(&dir.join("recsys.weights.bin"), &weights).unwrap();
    let mut artifacts = Vec::new();
    for b in [1usize, 4] {
        artifacts.push(format!(
            r#""recsys_fp32_b{b}": {{
              "hlo": "recsys_b{b}.hlo.txt", "model": "recsys",
              "weights": "recsys.weights.bin", "weight_params": [],
              "precision": "fp32", "program": {RECSYS_PROG},
              "inputs": [
                {{"name": "dense", "dtype": "f32", "shape": [{b}, 8]}},
                {{"name": "indices", "dtype": "i32", "shape": [{b}, 2, 4]}}
              ],
              "outputs": [{{"name": "prob", "dtype": "f32", "shape": [{b}, 1]}}],
              "batch": {b}
            }}"#
        ));
    }
    let manifest = format!(
        r#"{{
          "version": 1,
          "models": {{
            "recsys": {{"dense_dim": 8, "emb_dim": 8, "n_tables": 2, "pool": 4,
                        "rows_per_table": 64, "sparse_shards": {shards_meta}}}
          }},
          "artifacts": {{ {} }}
        }}"#,
        artifacts.join(",\n")
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    dir
}

#[test]
fn native_backend_embed_pool_fetches_through_the_tier() {
    let dir = fixture_dir("backend");
    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Pcg32::seeded(8);
    let mut dense = vec![0f32; 4 * 8];
    rng.fill_normal(&mut dense, 0.0, 1.0);
    let idx: Vec<i32> = (0..4 * 2 * 4).map(|_| rng.below(64) as i32).collect();
    let inputs = vec![
        HostTensor::from_f32(&[4, 8], &dense),
        HostTensor::from_i32(&[4, 2, 4], &idx),
    ];

    let local = NativeBackend::new(Precision::Fp32)
        .load(&manifest, "recsys_fp32_b4")
        .unwrap()
        .run(&inputs)
        .unwrap()[0]
        .as_f32()
        .unwrap();

    let tier = EmbeddingShardService::start(SparseTierConfig {
        shards: 3,
        replication: 1,
        cache_capacity_rows: 32,
        admit_after: 1,
        ..Default::default()
    })
    .unwrap();
    let sharded = NativeBackend::with_sparse_tier(Precision::Fp32, tier.clone())
        .load(&manifest, "recsys_fp32_b4")
        .unwrap();
    for _ in 0..2 {
        let got = sharded.run(&inputs).unwrap()[0].as_f32().unwrap();
        for (a, b) in local.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "local {a} vs sharded {b}");
        }
    }
    let s = tier.snapshot();
    assert!(s.lookups >= 4, "two runs x two tables route through the tier: {}", s.lookups);
    assert_eq!(s.tables.len(), 2);
    assert!(s.tables.iter().all(|t| t.key.starts_with("recsys.weights.bin/emb_")));
    assert!(s.tables.iter().all(|t| !t.quantized));

    // int8 execution registers row-quantized slices and stays in tolerance
    let int8 = NativeBackend::with_sparse_tier(Precision::I8Acc32, tier.clone())
        .load(&manifest, "recsys_fp32_b4")
        .unwrap();
    let got = int8.run(&inputs).unwrap()[0].as_f32().unwrap();
    let db = sqnr_db(&local, &got);
    assert!(db >= Precision::I8Acc32.min_sqnr_db(), "int8-over-tier sqnr {db:.1} dB");
    assert_eq!(tier.snapshot().tables.len(), 4, "int8 tables registered separately");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drifted_sparse_shard_metadata_fails_the_sharded_load_only() {
    let dir = fixture_dir_with_shards("drift", BAD_SHARDS);
    let manifest = Manifest::load(&dir).unwrap();
    // local path ignores the tier metadata entirely
    assert!(NativeBackend::new(Precision::Fp32).load(&manifest, "recsys_fp32_b1").is_ok());
    // sharded path validates it against the weights file before
    // registering anything into the shared tier
    let tier = EmbeddingShardService::start(SparseTierConfig::default()).unwrap();
    let err = NativeBackend::with_sparse_tier(Precision::Fp32, tier.clone())
        .load(&manifest, "recsys_fp32_b1")
        .expect_err("drifted sparse_shards metadata must fail the load");
    assert!(format!("{err:#}").contains("emb_1"), "{err:#}");
    assert!(tier.snapshot().tables.is_empty(), "nothing registered on failure");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn frontend_serves_through_sparse_tier_with_metrics() {
    let dir = fixture_dir("frontend");
    let manifest = Manifest::load(&dir).unwrap();
    let service = RecSysService::from_manifest(&manifest).unwrap();
    let frontend = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.clone(),
            executors: 2,
            max_wait_us: 500.0,
            backend: BackendSpec::native(Precision::Fp32),
            sparse_tier: Some(SparseTierConfig {
                shards: 3,
                replication: 1,
                cache_capacity_rows: 64,
                admit_after: 1,
                ..Default::default()
            }),
            ..Default::default()
        },
        vec![Arc::new(service.clone())],
    )
    .unwrap();

    let mut rng = Pcg32::seeded(55);
    let mut pending = Vec::new();
    for i in 0..30 {
        let mut req = service.synth_request(i, &mut rng, 200.0);
        req.arrival = Instant::now();
        pending.push(frontend.submit(req).unwrap());
    }
    for rx in pending {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(resp.is_ok(), "sparse-tier response failed: {:?}", resp.outcome.err());
        assert_eq!(resp.backend, "native/fp32");
    }

    // both executors share one tier: exactly one fp32 copy of each table
    let tier = frontend.sparse_tier().expect("tier configured").clone();
    let s = tier.snapshot();
    assert_eq!(s.tables.len(), 2, "2 executors x 2 variants share 2 tier tables: {:?}", s.tables);
    assert!(s.lookups > 0 && s.indices > 0);

    // the per-lane metrics snapshot carries the tier counters
    let snap = frontend.metrics(RecSysService::MODEL_ID).unwrap().snapshot();
    assert_eq!(snap.served, 30);
    assert_eq!(snap.failed, 0);
    let sparse = snap.sparse.expect("snapshot carries sparse tier stats");
    assert_eq!(sparse.shards, 3);
    let probed: u64 = sparse.tables.iter().map(|t| t.hits + t.misses).sum();
    assert!(probed > 0, "cache counters must reflect served traffic");

    frontend.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn frontend_without_sparse_tier_reports_none() {
    let dir = fixture_dir("notier");
    let manifest = Manifest::load(&dir).unwrap();
    let service = RecSysService::from_manifest(&manifest).unwrap();
    let frontend = ServingFrontend::start(
        FrontendConfig {
            artifacts_dir: dir.clone(),
            executors: 1,
            backend: BackendSpec::native(Precision::Fp32),
            ..Default::default()
        },
        vec![Arc::new(service.clone())],
    )
    .unwrap();
    assert!(frontend.sparse_tier().is_none());
    let snap = frontend.metrics(RecSysService::MODEL_ID).unwrap().snapshot();
    assert!(snap.sparse.is_none());
    frontend.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
