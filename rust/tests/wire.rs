//! Wire-format seal: property-style round trips over every dtype and
//! odd shapes, and rejection (typed errors, never panics) of
//! truncated, oversized, bad-version and garbage frames — the decode
//! surface a network server exposes to arbitrary peers.

use dcinfer::coordinator::wire::{self, FrameKind, WireError};
use dcinfer::coordinator::{
    InferError, InferRequest, InferResponse, SeqDone, SeqFinish, SeqRequest,
};
use dcinfer::runtime::{DType, HostTensor};
use dcinfer::util::rng::Pcg32;

fn random_tensor(rng: &mut Pcg32, dtype: DType, shape: &[usize]) -> HostTensor {
    let count: usize = shape.iter().product();
    match dtype {
        DType::F32 => {
            let mut vals = vec![0f32; count];
            rng.fill_normal(&mut vals, 0.0, 2.0);
            HostTensor::from_f32(shape, &vals)
        }
        DType::I32 => {
            let vals: Vec<i32> = (0..count).map(|_| rng.next_u32() as i32).collect();
            HostTensor::from_i32(shape, &vals)
        }
        DType::I8 => {
            let vals: Vec<i8> = (0..count).map(|_| rng.next_u32() as i8).collect();
            HostTensor::from_i8(shape, &vals)
        }
    }
}

fn assert_tensors_eq(a: &HostTensor, b: &HostTensor) {
    assert_eq!(a.dtype, b.dtype);
    assert_eq!(a.shape, b.shape);
    assert_eq!(a.data, b.data);
}

#[test]
fn requests_round_trip_over_all_dtypes_and_odd_shapes() {
    let mut rng = Pcg32::seeded(11);
    // rank 0 through rank 4, unit dims, zero dims, non-round sizes
    let shapes: Vec<Vec<usize>> = vec![
        vec![],
        vec![1],
        vec![7],
        vec![3, 1, 7],
        vec![2, 0, 4], // zero elements, still a legal tensor
        vec![1, 1, 1, 1],
        vec![5, 3],
    ];
    for dtype in [DType::F32, DType::I8, DType::I32] {
        for shape in &shapes {
            for deadline in [0.25f64, 100.0, 10_000.0] {
                let req = InferRequest::new(
                    "some_model",
                    rng.next_u64(),
                    vec![
                        random_tensor(&mut rng, dtype, shape),
                        random_tensor(&mut rng, DType::F32, &[2, 3]),
                    ],
                    deadline,
                );
                let back = wire::decode_request(&wire::encode_request(&req)).unwrap();
                assert_eq!(back.id, req.id);
                assert_eq!(back.model, req.model);
                assert_eq!(back.deadline_ms, req.deadline_ms);
                assert_eq!(back.inputs.len(), 2);
                for (a, b) in req.inputs.iter().zip(&back.inputs) {
                    assert_tensors_eq(a, b);
                }
            }
        }
    }
}

#[test]
fn requests_with_no_inputs_and_empty_model_round_trip() {
    let req = InferRequest::new("", 0, vec![], 1.0);
    let back = wire::decode_request(&wire::encode_request(&req)).unwrap();
    assert_eq!(back.model, "");
    assert!(back.inputs.is_empty());
}

#[test]
fn responses_round_trip_ok_and_all_error_variants() {
    let mut rng = Pcg32::seeded(23);
    let ok = InferResponse {
        id: 99,
        model: "nmt".into(),
        outcome: Ok(vec![
            random_tensor(&mut rng, DType::F32, &[16]),
            random_tensor(&mut rng, DType::F32, &[8]),
        ]),
        queue_us: 321.5,
        exec_us: 1234.25,
        batch_size: 4,
        variant: "gru_step_b4".into(),
        backend: "native/fp32".into(),
        replica: "replica-2".into(),
        degraded: true,
    };
    let back = wire::decode_response(&wire::encode_response(&ok)).unwrap();
    assert_eq!(back.id, 99);
    assert_eq!(back.model, "nmt");
    assert!(back.degraded, "degraded flag must survive the round trip");
    assert_eq!(back.queue_us, 321.5);
    assert_eq!(back.exec_us, 1234.25);
    assert_eq!(back.batch_size, 4);
    assert_eq!(back.variant, "gru_step_b4");
    assert_eq!(back.backend, "native/fp32");
    assert_eq!(back.replica, "replica-2");
    let (want, got) = (ok.outcome.as_ref().unwrap(), back.outcome.as_ref().unwrap());
    assert_eq!(want.len(), got.len());
    for (a, b) in want.iter().zip(got) {
        assert_tensors_eq(a, b);
    }

    for err in [
        InferError::UnknownModel("ghost".into()),
        InferError::BadRequest("wrong shape".into()),
        InferError::ExecFailed("device fell over".into()),
        InferError::Shutdown,
        InferError::Overloaded("queue depth 128 at bound 128".into()),
    ] {
        let mut r = ok.clone();
        r.outcome = Err(err.clone());
        let back = wire::decode_response(&wire::encode_response(&r)).unwrap();
        assert_eq!(back.outcome.unwrap_err(), err);
    }
}

#[test]
fn every_truncation_of_a_request_payload_is_a_typed_error() {
    let mut rng = Pcg32::seeded(37);
    let req = InferRequest::new(
        "recsys",
        7,
        vec![
            random_tensor(&mut rng, DType::F32, &[8]),
            random_tensor(&mut rng, DType::I32, &[2, 4]),
        ],
        50.0,
    );
    let payload = wire::encode_request(&req);
    for cut in 0..payload.len() {
        let err = wire::decode_request(&payload[..cut])
            .expect_err("every strict prefix must be rejected");
        assert!(
            matches!(err, WireError::Truncated { .. } | WireError::BadPayload(_)),
            "cut {cut}: unexpected {err}"
        );
    }
}

#[test]
fn every_truncation_of_a_response_payload_is_a_typed_error() {
    let mut rng = Pcg32::seeded(41);
    let resp = InferResponse {
        id: 1,
        model: "cv".into(),
        outcome: Ok(vec![random_tensor(&mut rng, DType::F32, &[4])]),
        queue_us: 1.0,
        exec_us: 2.0,
        batch_size: 2,
        variant: "cv_tiny_b2".into(),
        backend: "native/fp32".into(),
        replica: "r0".into(),
        degraded: false,
    };
    let payload = wire::encode_response(&resp);
    for cut in 0..payload.len() {
        assert!(
            wire::decode_response(&payload[..cut]).is_err(),
            "cut {cut} decoded"
        );
    }
}

#[test]
fn trailing_bytes_are_rejected() {
    let req = InferRequest::new("m", 1, vec![], 10.0);
    let mut payload = wire::encode_request(&req);
    payload.push(0);
    let err = wire::decode_request(&payload).unwrap_err();
    assert!(matches!(err, WireError::BadPayload(_)), "{err}");
}

#[test]
fn tensor_length_lies_are_rejected() {
    let req = InferRequest::new(
        "m",
        1,
        vec![HostTensor::from_f32(&[2], &[1.0, 2.0])],
        10.0,
    );
    let mut payload = wire::encode_request(&req);
    // the tensor sits after id(8) + deadline(8) + str16("m")(3) +
    // n_inputs(2); its layout is dtype(1) ndim(1) dim(4) data_len(4)
    let tensor_at = 8 + 8 + 3 + 2;
    let data_len_at = tensor_at + 1 + 1 + 4;
    // claim 12 bytes for a [2] f32 tensor (8 expected)
    payload[data_len_at..data_len_at + 4].copy_from_slice(&12u32.to_le_bytes());
    let err = wire::decode_request(&payload).unwrap_err();
    assert!(matches!(err, WireError::BadPayload(_)), "{err}");

    // and an unknown dtype code
    let mut payload = wire::encode_request(&req);
    payload[tensor_at] = 200;
    let err = wire::decode_request(&payload).unwrap_err();
    assert!(matches!(err, WireError::BadPayload(_)), "{err}");
}

#[test]
fn non_finite_deadlines_are_rejected() {
    let mut req = InferRequest::new("m", 1, vec![], 10.0);
    req.deadline_ms = f64::NAN;
    assert!(wire::decode_request(&wire::encode_request(&req)).is_err());
    req.deadline_ms = f64::INFINITY;
    assert!(wire::decode_request(&wire::encode_request(&req)).is_err());
}

#[test]
fn framed_stream_reads_back_and_rejects_corruption() {
    let req = InferRequest::new("m", 5, vec![HostTensor::from_i8(&[3], &[1, 2, 3])], 20.0);
    let payload = wire::encode_request(&req);
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, FrameKind::Request, 0xDEAD_BEEF, &payload).unwrap();

    // clean round trip
    let frame = wire::read_frame(&mut buf.as_slice(), wire::DEFAULT_MAX_FRAME).unwrap().unwrap();
    assert_eq!(frame.kind, FrameKind::Request);
    assert_eq!(frame.corr, 0xDEAD_BEEF);
    assert_eq!(wire::decode_request(&frame.payload).unwrap().id, 5);

    // truncated at every point inside the frame: typed error, no panic
    for cut in 1..buf.len() {
        let err = wire::read_frame(&mut &buf[..cut], wire::DEFAULT_MAX_FRAME)
            .expect_err("truncated frame accepted");
        assert!(
            matches!(err, WireError::Truncated { .. }),
            "cut {cut}: unexpected {err}"
        );
    }
    // EOF exactly between frames is a clean close
    assert!(wire::read_frame(&mut &buf[..0], wire::DEFAULT_MAX_FRAME).unwrap().is_none());

    // corrupt magic / version / kind
    let mut bad = buf.clone();
    bad[0] = b'x';
    assert!(matches!(
        wire::read_frame(&mut bad.as_slice(), wire::DEFAULT_MAX_FRAME),
        Err(WireError::BadMagic(_))
    ));
    let mut bad = buf.clone();
    bad[4] = 42;
    assert!(matches!(
        wire::read_frame(&mut bad.as_slice(), wire::DEFAULT_MAX_FRAME),
        Err(WireError::BadVersion(42))
    ));
    let mut bad = buf.clone();
    bad[5] = 99; // first unassigned kind (1-9 are request/response/shard/ping/seq)
    assert!(matches!(
        wire::read_frame(&mut bad.as_slice(), wire::DEFAULT_MAX_FRAME),
        Err(WireError::BadFrameKind(99))
    ));
}

/// Version skew against a *live* server: a peer speaking a future
/// protocol version (or an unknown frame kind) gets its connection
/// closed with a typed [`WireError`] server-side — and nothing else.
/// Other connections, including ones opened afterwards, are
/// untouched; the process never panics.
#[test]
fn version_skew_closes_only_the_offending_connection() {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::Arc;
    use std::time::Duration;

    use dcinfer::coordinator::{
        DcClient, FrontendConfig, ModelService, ServerConfig, ServingFrontend, ServingServer,
    };
    use dcinfer::models::RecSysService;
    use dcinfer::runtime::{synthetic_artifacts_dir, BackendSpec, Manifest, Precision};

    let dir = synthetic_artifacts_dir("wire_skew").expect("fixture");
    let manifest = Manifest::load(&dir).expect("manifest");
    let recsys = RecSysService::from_manifest(&manifest).expect("recsys config");
    let services: Vec<Arc<dyn ModelService>> = vec![Arc::new(recsys.clone())];
    let frontend = Arc::new(
        ServingFrontend::start(
            FrontendConfig {
                artifacts_dir: dir.clone(),
                executors: 1,
                backend: BackendSpec::native(Precision::Fp32),
                ..Default::default()
            },
            services,
        )
        .expect("frontend start"),
    );
    let server = ServingServer::bind(frontend.clone(), "127.0.0.1:0", ServerConfig::default())
        .expect("server bind");
    let addr = server.local_addr();

    // a well-behaved client, connected for the whole test
    let client = DcClient::connect(addr).expect("connect");
    let mut rng = Pcg32::seeded(6000);
    let cr = client.call(&recsys.synth_request(1, &mut rng, 500.0)).unwrap();
    assert!(cr.resp.is_ok(), "{:?}", cr.resp.outcome);

    // an otherwise perfectly valid frame, then skewed one field at a
    // time: header byte 4 is the version, byte 5 the frame kind
    let payload = wire::encode_request(&recsys.synth_request(2, &mut rng, 500.0));
    let mut good = Vec::new();
    wire::write_frame(&mut good, FrameKind::Request, 7, &payload).unwrap();

    for (at, val, what) in [(4usize, 9u8, "future version"), (5, 77, "unknown frame kind")] {
        let mut skewed = good.clone();
        skewed[at] = val;
        let mut raw = TcpStream::connect(addr).expect("raw connect");
        raw.write_all(&skewed).expect("write skewed frame");
        raw.flush().unwrap();
        // the server says nothing on an unspeakable frame — it just
        // closes this one connection
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = [0u8; 16];
        match raw.read(&mut buf) {
            Ok(0) => {}
            Err(e) if e.kind() != std::io::ErrorKind::WouldBlock
                && e.kind() != std::io::ErrorKind::TimedOut => {}
            Ok(k) => panic!("server answered {k} bytes to a {what} frame"),
            Err(e) => panic!("server kept a {what} connection open: {e}"),
        }
    }

    // the pre-existing client and the server are both unharmed
    let cr = client.call(&recsys.synth_request(3, &mut rng, 500.0)).unwrap();
    assert!(cr.resp.is_ok(), "{:?}", cr.resp.outcome);
    client.close();
    server.shutdown();
    frontend.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The whole streamed conversation of one sequence — submit, tokens,
/// done — written as frames into one buffer and read back: kinds,
/// correlation ids and payloads all survive, in order.
#[test]
fn seq_conversation_round_trips_through_a_framed_stream() {
    let mut rng = Pcg32::seeded(53);
    let req = SeqRequest::new(
        "nmt",
        41,
        vec![
            random_tensor(&mut rng, DType::F32, &[8]),
            random_tensor(&mut rng, DType::F32, &[8]),
        ],
        12,
        250.0,
    );
    let corr = 0xABCD_0001u64;
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, FrameKind::SeqSubmit, corr, &wire::encode_seq_submit(&req))
        .unwrap();
    for (step, token) in [(1u32, 7u32), (2, 9), (3, 0)] {
        wire::write_frame(&mut buf, FrameKind::SeqToken, corr, &wire::encode_seq_token(step, token))
            .unwrap();
    }
    let done = SeqDone { steps: 3, outcome: Ok(SeqFinish::Eos) };
    wire::write_frame(&mut buf, FrameKind::SeqDone, corr, &wire::encode_seq_done(&done)).unwrap();

    let mut rd = buf.as_slice();
    let f = wire::read_frame(&mut rd, wire::DEFAULT_MAX_FRAME).unwrap().unwrap();
    assert_eq!((f.kind, f.corr), (FrameKind::SeqSubmit, corr));
    let back = wire::decode_seq_submit(&f.payload).unwrap();
    assert_eq!((back.id, back.max_len, back.deadline_ms), (41, 12, 250.0));
    assert_eq!(back.model, "nmt");
    assert_eq!(back.inputs.len(), 2);
    for (a, b) in req.inputs.iter().zip(&back.inputs) {
        assert_tensors_eq(a, b);
    }
    for want in [(1u32, 7u32), (2, 9), (3, 0)] {
        let f = wire::read_frame(&mut rd, wire::DEFAULT_MAX_FRAME).unwrap().unwrap();
        assert_eq!((f.kind, f.corr), (FrameKind::SeqToken, corr));
        assert_eq!(wire::decode_seq_token(&f.payload).unwrap(), want);
    }
    let f = wire::read_frame(&mut rd, wire::DEFAULT_MAX_FRAME).unwrap().unwrap();
    assert_eq!(f.kind, FrameKind::SeqDone);
    let back = wire::decode_seq_done(&f.payload).unwrap();
    assert_eq!(back.steps, 3);
    assert_eq!(back.outcome, Ok(SeqFinish::Eos));
    // and the stream ends cleanly
    assert!(wire::read_frame(&mut rd, wire::DEFAULT_MAX_FRAME).unwrap().is_none());
}

/// Every strict prefix of every seq payload is a typed error, and the
/// error half of `SeqDone` round-trips for each `InferError` variant.
#[test]
fn seq_payload_truncations_and_error_outcomes_are_typed() {
    let mut rng = Pcg32::seeded(59);
    let req = SeqRequest::new(
        "nmt",
        5,
        vec![random_tensor(&mut rng, DType::F32, &[4])],
        8,
        0.0,
    );
    let payloads = [
        wire::encode_seq_submit(&req),
        wire::encode_seq_token(3, 11),
        wire::encode_seq_done(&SeqDone { steps: 2, outcome: Ok(SeqFinish::MaxLen) }),
        wire::encode_seq_done(&SeqDone {
            steps: 0,
            outcome: Err(InferError::Overloaded("table full".into())),
        }),
    ];
    for (which, payload) in payloads.iter().enumerate() {
        for cut in 0..payload.len() {
            let err = match which {
                0 => wire::decode_seq_submit(&payload[..cut]).map(|_| ()).unwrap_err(),
                1 => wire::decode_seq_token(&payload[..cut]).map(|_| ()).unwrap_err(),
                _ => wire::decode_seq_done(&payload[..cut]).map(|_| ()).unwrap_err(),
            };
            assert!(
                matches!(err, WireError::Truncated { .. } | WireError::BadPayload(_)),
                "payload {which} cut {cut}: unexpected {err}"
            );
        }
    }

    for err in [
        InferError::UnknownModel("ghost".into()),
        InferError::BadRequest("short state".into()),
        InferError::ExecFailed("backend".into()),
        InferError::Shutdown,
        InferError::Overloaded("bound".into()),
    ] {
        let done = SeqDone { steps: 4, outcome: Err(err.clone()) };
        let back = wire::decode_seq_done(&wire::encode_seq_done(&done)).unwrap();
        assert_eq!(back.steps, 4);
        assert_eq!(back.outcome.unwrap_err(), err);
    }
}

/// A submit whose tensor header lies about its data length (and one
/// with a zero `max_len`) must be refused, never mis-sliced.
#[test]
fn seq_submit_length_lies_and_zero_max_len_are_rejected() {
    let req = SeqRequest::new(
        "m",
        1,
        vec![HostTensor::from_f32(&[2], &[1.0, 2.0])],
        6,
        10.0,
    );
    let mut payload = wire::encode_seq_submit(&req);
    // layout: id(8) deadline(8) max_len(4) str16("m")(3) n_inputs(2),
    // then the tensor as dtype(1) ndim(1) dim(4) data_len(4) data
    let tensor_at = 8 + 8 + 4 + 3 + 2;
    let data_len_at = tensor_at + 1 + 1 + 4;
    payload[data_len_at..data_len_at + 4].copy_from_slice(&12u32.to_le_bytes());
    let err = wire::decode_seq_submit(&payload).unwrap_err();
    assert!(matches!(err, WireError::BadPayload(_)), "{err}");

    let mut zero = req;
    zero.max_len = 0;
    let err = wire::decode_seq_submit(&wire::encode_seq_submit(&zero)).unwrap_err();
    assert!(matches!(err, WireError::BadPayload(_)), "{err}");
}

#[test]
fn oversized_frames_are_rejected_before_allocation() {
    let payload = vec![0u8; 1024];
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, FrameKind::Response, 1, &payload).unwrap();
    // a receiver with a 512-byte bound refuses the 1 KiB frame
    let err = wire::read_frame(&mut buf.as_slice(), 512).unwrap_err();
    assert!(matches!(err, WireError::Oversized { len: 1024, max: 512 }), "{err}");
    // garbage lengths never cause a giant allocation: craft a header
    // claiming u32::MAX bytes
    let mut header = buf[..wire::HEADER_LEN].to_vec();
    header[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = wire::read_frame(&mut header.as_slice(), wire::DEFAULT_MAX_FRAME).unwrap_err();
    assert!(matches!(err, WireError::Oversized { .. }), "{err}");
}
